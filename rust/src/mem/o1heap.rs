//! Deterministic constant-complexity heap allocator.
//!
//! §2.4: "The implementation uses a deterministic constant-complexity memory
//! allocator [o1heap, 32][33], ensures mutual exclusivity among all affected
//! cores through RISC-V atomic operations, and can detect heap overflows
//! with a canary mechanism. The alignment and minimum allocation granule is
//! 8 B."
//!
//! This is a half-fit allocator in the style of o1heap: free blocks are kept
//! in segregated lists by power-of-two size class; allocation rounds the
//! request up to the next power of two, takes the head of the first
//! non-empty list of sufficient class (O(1) via a bitmask), and splits the
//! remainder back into the lists. Free coalesces with the physically
//! adjacent blocks in O(1) via boundary metadata.
//!
//! The allocator manages *offsets into a simulated SPM region*; block
//! headers live in allocator state (as the device-side headers would occupy
//! SPM in hardware, the capacity accounting subtracts them), and the canary
//! word is actually written to simulated memory so that heap overruns by
//! simulated kernels are detected on `free`.

/// Allocation granule and alignment (bytes).
pub const GRANULE: u32 = 8;
/// Canary value written after each live block.
pub const CANARY: u32 = 0x5AFE_CAFE;
/// Per-block bookkeeping overhead charged against capacity (header word +
/// canary word, rounded to the granule).
pub const BLOCK_OVERHEAD: u32 = 8;

const NUM_CLASSES: usize = 27; // up to 2^26 = 64 MiB regions

#[derive(Debug, Clone, Copy, PartialEq)]
struct Block {
    off: u32,
    size: u32,
    free: bool,
    prev_phys: i32, // index into blocks, -1 = none
    next_phys: i32,
}

/// Outcome of a `free` call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FreeResult {
    Ok,
    /// The canary after the block was overwritten — heap overflow detected.
    CanaryCorrupted,
}

/// A deterministic O(1) allocator over a `[base, base+capacity)` region of
/// device memory.
#[derive(Debug, Clone)]
pub struct O1Heap {
    #[allow(dead_code)]
    base: u32,
    capacity: u32,
    free_heads: [i32; NUM_CLASSES],
    nonempty_mask: u32,
    blocks: Vec<Block>,
    free_block_slots: Vec<i32>,
    /// next free-list link per block (parallel to `blocks`).
    next_free: Vec<i32>,
    prev_free: Vec<i32>,
    allocated_bytes: u32,
}

fn class_of(size: u32) -> usize {
    // Smallest class c with 2^c >= size; granule floor.
    let s = size.max(GRANULE);
    (32 - (s - 1).leading_zeros()) as usize
}

impl O1Heap {
    /// Create an allocator over `capacity` bytes starting at device offset
    /// `base`. Both must be granule-aligned.
    pub fn new(base: u32, capacity: u32) -> Self {
        assert_eq!(base % GRANULE, 0);
        assert_eq!(capacity % GRANULE, 0);
        let mut h = O1Heap {
            base,
            capacity,
            free_heads: [-1; NUM_CLASSES],
            nonempty_mask: 0,
            blocks: Vec::new(),
            free_block_slots: Vec::new(),
            next_free: Vec::new(),
            prev_free: Vec::new(),
            allocated_bytes: 0,
        };
        let b = h.new_block(Block { off: base, size: capacity, free: true, prev_phys: -1, next_phys: -1 });
        h.push_free(b);
        h
    }

    fn new_block(&mut self, b: Block) -> i32 {
        if let Some(slot) = self.free_block_slots.pop() {
            self.blocks[slot as usize] = b;
            self.next_free[slot as usize] = -1;
            self.prev_free[slot as usize] = -1;
            slot
        } else {
            self.blocks.push(b);
            self.next_free.push(-1);
            self.prev_free.push(-1);
            (self.blocks.len() - 1) as i32
        }
    }

    fn free_class(&self, size: u32) -> usize {
        // Largest class c with 2^c <= size (a free block of `size` can serve
        // requests up to 2^c).
        (31 - size.leading_zeros()) as usize
    }

    fn push_free(&mut self, idx: i32) {
        let c = self.free_class(self.blocks[idx as usize].size);
        let head = self.free_heads[c];
        self.next_free[idx as usize] = head;
        self.prev_free[idx as usize] = -1;
        if head >= 0 {
            self.prev_free[head as usize] = idx;
        }
        self.free_heads[c] = idx;
        self.nonempty_mask |= 1 << c;
        self.blocks[idx as usize].free = true;
    }

    fn unlink_free(&mut self, idx: i32) {
        let c = self.free_class(self.blocks[idx as usize].size);
        let (p, n) = (self.prev_free[idx as usize], self.next_free[idx as usize]);
        if p >= 0 {
            self.next_free[p as usize] = n;
        } else {
            self.free_heads[c] = n;
            if n < 0 {
                self.nonempty_mask &= !(1 << c);
            }
        }
        if n >= 0 {
            self.prev_free[n as usize] = p;
        }
        self.blocks[idx as usize].free = false;
    }

    /// Currently available heap memory in bytes (`hero_lN_capacity`): the
    /// total free bytes minus per-block overhead that a subsequent
    /// allocation would consume.
    pub fn capacity_remaining(&self) -> u32 {
        self.capacity - self.allocated_bytes
    }

    /// Total managed capacity.
    pub fn capacity_total(&self) -> u32 {
        self.capacity
    }

    /// Allocate `size` bytes; returns the device address of the payload.
    /// The canary is written to `mem_canary` (a callback storing a word into
    /// simulated memory at a byte offset).
    pub fn malloc(&mut self, size: u32, mut write_word: impl FnMut(u32, u32)) -> Option<u32> {
        if size == 0 {
            return None;
        }
        // Round payload to granule and add the canary slot.
        let payload = (size + GRANULE - 1) / GRANULE * GRANULE;
        let need = payload + BLOCK_OVERHEAD;
        let c = class_of(need);
        // O(1): find the lowest non-empty class >= c via the bitmask.
        let mask = self.nonempty_mask >> c << c;
        if mask == 0 {
            return None;
        }
        let cls = mask.trailing_zeros() as usize;
        let idx = self.free_heads[cls];
        debug_assert!(idx >= 0);
        self.unlink_free(idx);
        let blk = self.blocks[idx as usize];
        debug_assert!(blk.size >= need);
        let rem = blk.size - need;
        if rem >= GRANULE + BLOCK_OVERHEAD {
            // Split: shrink this block, create the tail as free.
            self.blocks[idx as usize].size = need;
            let next_phys = blk.next_phys;
            let tail = self.new_block(Block {
                off: blk.off + need,
                size: rem,
                free: true,
                prev_phys: idx,
                next_phys,
            });
            if next_phys >= 0 {
                self.blocks[next_phys as usize].prev_phys = tail;
            }
            self.blocks[idx as usize].next_phys = tail;
            self.push_free(tail);
        }
        self.allocated_bytes += self.blocks[idx as usize].size;
        let addr = blk.off + (BLOCK_OVERHEAD - 4); // header word precedes payload
        // Canary directly after the payload.
        write_word(addr + payload, CANARY);
        Some(addr)
    }

    fn find_block(&self, payload_addr: u32) -> Option<i32> {
        let off = payload_addr - (BLOCK_OVERHEAD - 4);
        // O(1) in hardware via the header; linear scan here is fine for the
        // model (allocation counts are small), but keep it correct.
        (0..self.blocks.len() as i32).find(|&i| {
            let b = self.blocks[i as usize];
            !b.free && b.off == off && !self.is_slot_free(i)
        })
    }

    fn is_slot_free(&self, idx: i32) -> bool {
        self.free_block_slots.contains(&idx)
    }

    /// Free a previously allocated address, checking the canary via
    /// `read_word`.
    pub fn free(&mut self, addr: u32, mut read_word: impl FnMut(u32) -> u32) -> FreeResult {
        let idx = self.find_block(addr).expect("free of unallocated address");
        let blk = self.blocks[idx as usize];
        let payload = blk.size - BLOCK_OVERHEAD;
        let canary_ok = read_word(addr + payload) == CANARY;
        self.allocated_bytes -= blk.size;
        // Coalesce with physical neighbours (O(1)).
        let mut cur = idx;
        if blk.prev_phys >= 0 && self.blocks[blk.prev_phys as usize].free {
            let p = blk.prev_phys;
            self.unlink_free(p);
            let cur_next = self.blocks[cur as usize].next_phys;
            self.blocks[p as usize].size += self.blocks[cur as usize].size;
            self.blocks[p as usize].next_phys = cur_next;
            if cur_next >= 0 {
                self.blocks[cur_next as usize].prev_phys = p;
            }
            self.free_block_slots.push(cur);
            cur = p;
        }
        let nxt = self.blocks[cur as usize].next_phys;
        if nxt >= 0 && self.blocks[nxt as usize].free {
            self.unlink_free(nxt);
            let nxt_next = self.blocks[nxt as usize].next_phys;
            self.blocks[cur as usize].size += self.blocks[nxt as usize].size;
            self.blocks[cur as usize].next_phys = nxt_next;
            if nxt_next >= 0 {
                self.blocks[nxt_next as usize].prev_phys = cur;
            }
            self.free_block_slots.push(nxt);
        }
        self.push_free(cur);
        if canary_ok {
            FreeResult::Ok
        } else {
            FreeResult::CanaryCorrupted
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn mem() -> HashMap<u32, u32> {
        HashMap::new()
    }

    #[test]
    fn alloc_free_roundtrip() {
        let mut m = mem();
        let mut h = O1Heap::new(0, 1024);
        let a = h.malloc(100, |o, v| { m.insert(o, v); }).unwrap();
        assert_eq!(a % 4, 0);
        assert_eq!(h.free(a, |o| m[&o]), FreeResult::Ok);
        assert_eq!(h.capacity_remaining(), 1024);
    }

    #[test]
    fn canary_detects_overflow() {
        let mut m = mem();
        let mut h = O1Heap::new(0, 1024);
        let a = h.malloc(16, |o, v| { m.insert(o, v); }).unwrap();
        // Simulated kernel writes past the end of its 16-byte buffer.
        m.insert(a + 16, 0x1234_5678);
        assert_eq!(h.free(a, |o| m[&o]), FreeResult::CanaryCorrupted);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut m = mem();
        let mut h = O1Heap::new(0, 256);
        let mut got = Vec::new();
        while let Some(a) = h.malloc(64, |o, v| { m.insert(o, v); }) {
            got.push(a);
        }
        assert!(!got.is_empty());
        assert!(h.malloc(64, |o, v| { m.insert(o, v); }).is_none());
        // Free everything: capacity fully restored (coalescing works).
        for a in got {
            assert_eq!(h.free(a, |o| m[&o]), FreeResult::Ok);
        }
        assert_eq!(h.capacity_remaining(), 256);
        // And a big block is allocatable again.
        assert!(h.malloc(200, |o, v| { m.insert(o, v); }).is_some());
    }

    #[test]
    fn no_overlap_among_live_blocks() {
        let mut m = mem();
        let mut h = O1Heap::new(4096, 4096);
        let sizes = [8, 24, 100, 8, 512, 64, 17, 40];
        let mut live: Vec<(u32, u32)> = Vec::new();
        for (i, &s) in sizes.iter().enumerate() {
            if let Some(a) = h.malloc(s, |o, v| { m.insert(o, v); }) {
                assert!(a >= 4096 && a + s <= 8192, "block outside region");
                for &(b, bs) in &live {
                    assert!(a + s <= b || b + bs <= a, "overlap: ({a},{s}) vs ({b},{bs})");
                }
                live.push((a, s));
            }
            // Free every other allocation to exercise coalescing paths.
            if i % 2 == 1 && !live.is_empty() {
                let (a, _) = live.remove(0);
                assert_eq!(h.free(a, |o| m[&o]), FreeResult::Ok);
            }
        }
    }

    #[test]
    fn granule_alignment() {
        let mut m = mem();
        let mut h = O1Heap::new(0, 1024);
        for s in [1, 7, 8, 9, 15] {
            let a = h.malloc(s, |o, v| { m.insert(o, v); }).unwrap();
            assert_eq!(a % 4, 0, "size {s} gave unaligned {a}");
            h.free(a, |o| m[&o]);
        }
    }

    #[test]
    fn zero_size_rejected() {
        let mut m = mem();
        let mut h = O1Heap::new(0, 1024);
        assert!(h.malloc(0, |o, v| { m.insert(o, v); }).is_none());
    }
}
