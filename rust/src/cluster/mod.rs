//! Accelerator cluster: cores, TCDM, shared icache, DMA engine, event unit.
//!
//! §2.1: "The accelerator is composed of many minimal 32-bit RISC-V cores,
//! which are organized into clusters of 4 to 16 cores for scalability. ...
//! Within each accelerator cluster, the cores have single-cycle access to a
//! multi-banked, tightly-coupled L1 data SPM. ... The cores fetch their
//! instructions from an L1 instruction cache, which is shared by all cores
//! in one cluster. To reduce the pressure on the shared instruction cache
//! during loops, each core additionally contains an L0 instruction cache
//! holding up to eight compressed instructions."
//!
//! This module holds the cluster *state*; instruction execution lives in
//! [`crate::accel`], which owns the cross-cluster resources (L2, DRAM,
//! IOMMU).

use crate::config::HeroConfig;
use crate::dma::DmaEngine;
use crate::isa::Program;
use crate::mem::{DramPort, Tcdm};
use crate::noc::{Port, WidePath};
use crate::trace::PerfCounters;
use std::sync::Arc;

/// Hardware-loop register state (two nested loops, Xpulpv2 `lp.setup`).
#[derive(Debug, Clone, Copy, Default)]
pub struct HwLoopState {
    pub start: u32,
    pub end: u32,
    /// Remaining iterations; 0 = inactive.
    pub count: u32,
}

/// Execution state of one core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreState {
    /// Parked in the event unit, waiting for a `Fork` (or initial wakeup).
    Sleeping,
    /// Executing instructions.
    Running,
    /// Blocked on DMA transfer completion (`dma.wait`).
    WaitDma { id: u32 },
    /// Arrived at a `Barrier`/`Join`, waiting for the others.
    WaitBarrier {
        /// True if this is a `Join` (end of parallel region): workers go
        /// back to sleep on release, the master falls through.
        join: bool,
    },
    /// Finished (`halt`). Core 0 halting ends the cluster's offload share.
    Halted,
}

/// One accelerator core (CV32E40P-style: single-issue, in-order, 1–4 stage).
#[derive(Debug, Clone)]
pub struct Core {
    /// Core index within the cluster (CSR `mhartid`).
    pub id: usize,
    pub state: CoreState,
    /// Next instruction index to execute.
    pub pc: u32,
    /// Integer register file; x0 is hardwired to zero.
    pub regs: [u32; 32],
    /// Float register file.
    pub fregs: [f32; 32],
    /// Address-extension CSR: upper 32 bits for host-address-space accesses.
    pub ext_addr: u32,
    /// Hardware loops (index 0 = innermost by convention).
    pub hwloop: [HwLoopState; 2],
    /// The core is stalled (memory latency, fetch, setup) until this cycle.
    pub stall_until: u64,
    /// L0 loop-buffer window base: holds instructions
    /// `[l0_base, l0_base + l0_insts)`.
    pub l0_base: u32,
    /// Per-core performance counters.
    pub perf: PerfCounters,
}

impl Core {
    pub fn new(id: usize) -> Self {
        Core {
            id,
            state: if id == 0 { CoreState::Running } else { CoreState::Sleeping },
            pc: 0,
            regs: [0; 32],
            fregs: [0.0; 32],
            ext_addr: 0,
            hwloop: [HwLoopState::default(); 2],
            stall_until: 0,
            l0_base: 0,
            perf: PerfCounters::new(),
        }
    }

    /// Reset architectural state for a new offload (perf counters persist;
    /// the runtime snapshots them around regions of interest).
    pub fn reset_for_offload(&mut self, entry: u32) {
        self.state = if self.id == 0 { CoreState::Running } else { CoreState::Sleeping };
        self.pc = entry;
        self.regs = [0; 32];
        self.fregs = [0.0; 32];
        self.ext_addr = 0;
        self.hwloop = [HwLoopState::default(); 2];
        self.stall_until = 0;
        self.l0_base = entry;
    }

    /// Read a register (x0 reads as zero).
    #[inline(always)]
    pub fn reg(&self, r: u8) -> u32 {
        self.regs[r as usize]
    }

    /// Write a register (writes to x0 are discarded).
    #[inline(always)]
    pub fn set_reg(&mut self, r: u8, v: u32) {
        if r != 0 {
            self.regs[r as usize] = v;
        }
    }
}

/// Deterministic extra TCDM-conflict rate (parts per million) applied when
/// the wide NoC is ≥128 bit: §3.3 observes that widening the DMA interface
/// forces the TCDM interconnect from 14×16 to 18×32, causing "on average
/// 15 % more contention ... despite the higher number of banks" because the
/// cores' alignment on the interconnect is no longer optimal. We model the
/// misalignment as a deterministic pseudo-random extra arbitration stall.
pub const WIDE_TCDM_SKEW_PPM: u64 = 62_000;

/// A cluster: cores + TCDM + shared icache + DMA engine + event unit state.
#[derive(Debug)]
pub struct Cluster {
    pub id: usize,
    pub cores: Vec<Core>,
    pub tcdm: Tcdm,
    pub dma: DmaEngine,
    /// Program loaded by the offload runtime (shared text segment).
    pub program: Arc<Program>,
    /// Direct-mapped shared icache: tag per line slot (`u32::MAX` = empty).
    pub icache_tags: Vec<u32>,
    /// Serializing refill port of the shared icache.
    pub refill_port: Port,
    /// Narrow-NoC port for core-initiated remote accesses.
    pub narrow_port: Port,
    /// Per-cycle TCDM bank claims (stamped with the claiming cycle).
    pub bank_claim: Vec<u64>,
    /// Core id that issued the last `Fork` (the parallel-region master).
    pub fork_master: usize,
    /// Extra conflict probability in ppm (see [`WIDE_TCDM_SKEW_PPM`]).
    pub extra_conflict_ppm: u64,
    /// Per-instruction fast-path eligibility, precomputed at program load
    /// (instructions touching remote memory, DMA, or the event unit always
    /// take the interpreter's slow path).
    pub fast_mask: Vec<bool>,
    /// Cores currently parked at a barrier (cheap pre-check for the
    /// per-cycle release scan).
    pub barrier_waiters: u32,
}

impl Cluster {
    /// `dram_port` is this cluster's DMA requester port on the board's
    /// shared DRAM (registered by the accelerator that owns both).
    pub fn new(id: usize, cfg: &HeroConfig, dram_port: DramPort) -> Self {
        let n_banks = cfg.tcdm_banks();
        let n_lines = (cfg.accel.icache_bytes / 4 / cfg.accel.icache_line_insts).max(1);
        let path = WidePath {
            beat_bytes: cfg.dma_beat_bytes(),
            burst_overhead: cfg.dma.burst_overhead,
            first_word: cfg.dram.first_word_cycles,
            max_burst_beats: cfg.dma.max_burst_beats as u64,
        };
        Cluster {
            id,
            cores: (0..cfg.accel.cores_per_cluster).map(Core::new).collect(),
            tcdm: Tcdm::new(cfg.accel.l1_bytes, n_banks),
            dma: DmaEngine::new(path, cfg.dma.setup_cycles, dram_port),
            program: Arc::new(Program::default()),
            icache_tags: vec![u32::MAX; n_lines],
            refill_port: Port::new(),
            narrow_port: Port::new(),
            bank_claim: vec![u64::MAX; n_banks.max(1)],
            fork_master: 0,
            extra_conflict_ppm: if cfg.noc.dma_width_bits >= 128 { WIDE_TCDM_SKEW_PPM } else { 0 },
            fast_mask: Vec::new(),
            barrier_waiters: 0,
        }
    }

    /// Load a program and reset cores for an offload starting at `entry`.
    pub fn load_program(&mut self, program: Arc<Program>) {
        let entry = program.entry;
        use crate::isa::Inst as I;
        self.fast_mask = program
            .insts
            .iter()
            .map(|i| {
                !matches!(
                    i,
                    I::LwExt { .. }
                        | I::SwExt { .. }
                        | I::FlwExt { .. }
                        | I::FswExt { .. }
                        | I::DmaStart1D { .. }
                        | I::DmaStart2D { .. }
                        | I::DmaWait { .. }
                        | I::Fork { .. }
                        | I::Join
                        | I::Barrier
                        | I::PerfCtl { .. }
                        | I::Halt
                        | I::CsrW { .. }
                        | I::Amo { .. }
                        | I::Jalr { .. }
                )
            })
            .collect();
        self.barrier_waiters = 0;
        self.program = program;
        for core in &mut self.cores {
            core.reset_for_offload(entry);
        }
        for t in &mut self.icache_tags {
            *t = u32::MAX;
        }
        self.bank_claim.fill(u64::MAX);
        self.dma.reset();
    }

    /// Whether every non-sleeping, non-halted core has arrived at a barrier.
    pub fn barrier_ready(&self) -> bool {
        let mut any = false;
        for c in &self.cores {
            match c.state {
                CoreState::WaitBarrier { .. } => any = true,
                CoreState::Sleeping | CoreState::Halted => {}
                _ => return false,
            }
        }
        any
    }

    /// Release a completed barrier at cycle `now`: everyone pays the event
    /// unit cost; `Join` workers go back to sleep.
    pub fn release_barrier(&mut self, now: u64, barrier_cost: u64) {
        self.barrier_waiters = 0;
        let master = self.fork_master;
        for c in &mut self.cores {
            if let CoreState::WaitBarrier { join } = c.state {
                c.perf.bump(crate::trace::Event::Barrier);
                c.stall_until = now + barrier_cost;
                if join && c.id != master {
                    c.state = CoreState::Sleeping;
                } else {
                    c.state = CoreState::Running;
                }
            }
        }
    }

    /// Aggregate perf counters over all cores.
    pub fn perf_aggregate(&self) -> PerfCounters {
        let mut agg = PerfCounters::new();
        for c in &self.cores {
            agg.merge(&c.perf);
        }
        agg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::aurora;
    use crate::isa::Inst;
    use crate::mem::SharedDram;

    fn test_cluster(cfg: &HeroConfig) -> Cluster {
        let mut dram = SharedDram::new(0, cfg.dram.bytes_per_cycle, 0);
        Cluster::new(0, cfg, dram.add_port("cluster0-dma", false))
    }

    #[test]
    fn new_cluster_geometry() {
        let cfg = aurora();
        let cl = test_cluster(&cfg);
        assert_eq!(cl.cores.len(), 8);
        assert_eq!(cl.tcdm.n_banks(), 16);
        assert_eq!(cl.cores[0].state, CoreState::Running);
        assert_eq!(cl.cores[1].state, CoreState::Sleeping);
        assert_eq!(cl.extra_conflict_ppm, 0);
    }

    #[test]
    fn wide_noc_enables_skew() {
        let mut cfg = aurora();
        cfg.noc.dma_width_bits = 128;
        let cl = test_cluster(&cfg);
        assert_eq!(cl.extra_conflict_ppm, WIDE_TCDM_SKEW_PPM);
    }

    #[test]
    fn x0_is_hardwired_zero() {
        let mut c = Core::new(0);
        c.set_reg(0, 42);
        assert_eq!(c.reg(0), 0);
        c.set_reg(5, 42);
        assert_eq!(c.reg(5), 42);
    }

    #[test]
    fn barrier_ready_logic() {
        let cfg = aurora();
        let mut cl = test_cluster(&cfg);
        cl.load_program(Arc::new(Program::new(vec![Inst::Halt])));
        // Only core 0 running, not at barrier: not ready.
        assert!(!cl.barrier_ready());
        cl.cores[0].state = CoreState::WaitBarrier { join: false };
        assert!(cl.barrier_ready());
        // Wake a second core that hasn't arrived: not ready.
        cl.cores[1].state = CoreState::Running;
        assert!(!cl.barrier_ready());
        cl.cores[1].state = CoreState::WaitBarrier { join: true };
        assert!(cl.barrier_ready());
        cl.release_barrier(100, 20);
        assert_eq!(cl.cores[0].state, CoreState::Running);
        assert_eq!(cl.cores[1].state, CoreState::Sleeping); // join worker
        assert_eq!(cl.cores[0].stall_until, 120);
    }

    #[test]
    fn load_program_resets_cores() {
        let cfg = aurora();
        let mut cl = test_cluster(&cfg);
        cl.cores[3].pc = 99;
        cl.cores[3].state = CoreState::Halted;
        let mut p = Program::new(vec![Inst::Nop, Inst::Halt]);
        p.entry = 1;
        cl.load_program(Arc::new(p));
        assert_eq!(cl.cores[3].pc, 1);
        assert_eq!(cl.cores[3].state, CoreState::Sleeping);
        assert_eq!(cl.cores[0].state, CoreState::Running);
    }
}
