//! The HERO application programming interface (§2.4).
//!
//! Three families of functionality, unified over all accelerators:
//! SPM **memory management** (`hero_lN_capacity` / `hero_lN_malloc` /
//! `hero_lN_free` — a deterministic constant-complexity allocator with a
//! canary), **data transfers** (`hero_memcpy_*`: direction × synchronicity ×
//! dimensionality), and **performance measurement** (dynamically allocated
//! hardware counters with pause/continue).
//!
//! This is the host-callable embodiment of the API for tests, examples and
//! tooling; the device-side embodiment is what the compiler lowers `Dma`
//! statements and perf controls to.

use crate::accel::Accel;
use crate::dma::Descriptor;
use crate::isa::DmaDir;
use crate::mem::{map, o1heap, O1Heap};
use crate::trace::{Event, PerfCounters};
use anyhow::{anyhow, bail, Result};

/// SPM level selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpmLevel {
    /// Per-cluster TCDM.
    L1(usize),
    /// Shared L2 SPM.
    L2,
}

/// A pending asynchronous transfer id (`hero_memcpy_*_async` return value).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferId {
    cluster: usize,
    id: u32,
}

/// The HERO API bound to one accelerator instance.
pub struct HeroApi {
    l1: Vec<O1Heap>,
    l2: O1Heap,
}

impl HeroApi {
    /// Initialize heaps: the user portion of each cluster's TCDM (above the
    /// runtime reserve) and the upper half of L2.
    pub fn new(accel: &Accel) -> Self {
        let l1_bytes = accel.cfg.accel.l1_bytes as u32;
        let reserve = l1_bytes / 8;
        let l1 = (0..accel.clusters.len())
            .map(|cl| O1Heap::new(map::tcdm_base(cl) + reserve, l1_bytes - reserve))
            .collect();
        let l2_bytes = accel.cfg.accel.l2_bytes as u32;
        let l2 = O1Heap::new(map::L2_BASE + l2_bytes / 2, l2_bytes / 2);
        HeroApi { l1, l2 }
    }

    /// `hero_lN_capacity`: currently available heap memory at this level.
    /// A read-only query, so it borrows the API immutably (callers like the
    /// scheduler's admission control hold no exclusive access).
    pub fn capacity(&self, level: SpmLevel) -> u32 {
        match level {
            SpmLevel::L1(cl) => self.l1[cl].capacity_remaining(),
            SpmLevel::L2 => self.l2.capacity_remaining(),
        }
    }

    /// `hero_lN_malloc`: allocate `bytes`, returning a device address.
    /// The canary is written into simulated SPM.
    pub fn malloc(&mut self, accel: &mut Accel, level: SpmLevel, bytes: u32) -> Option<u32> {
        let heap = match level {
            SpmLevel::L1(cl) => &mut self.l1[cl],
            SpmLevel::L2 => &mut self.l2,
        };
        heap.malloc(bytes, |addr, v| store_dev(accel, addr, v))
    }

    /// `hero_lN_free`: free and check the canary.
    pub fn free(
        &mut self,
        accel: &mut Accel,
        level: SpmLevel,
        addr: u32,
    ) -> o1heap::FreeResult {
        let heap = match level {
            SpmLevel::L1(cl) => &mut self.l1[cl],
            SpmLevel::L2 => &mut self.l2,
        };
        heap.free(addr, |a| load_dev(accel, a))
    }

    /// `hero_memcpy_host2dev_async` (1D).
    pub fn memcpy_host2dev_async(
        &mut self,
        accel: &mut Accel,
        dev: u32,
        host_va: u64,
        bytes: u32,
    ) -> Result<TransferId> {
        self.start(accel, DmaDir::HostToDev, dev, host_va, bytes, 1, 0, 0, true)
    }

    /// `hero_memcpy_dev2host_async` (1D).
    pub fn memcpy_dev2host_async(
        &mut self,
        accel: &mut Accel,
        host_va: u64,
        dev: u32,
        bytes: u32,
    ) -> Result<TransferId> {
        self.start(accel, DmaDir::DevToHost, dev, host_va, bytes, 1, 0, 0, true)
    }

    /// `hero_memcpy2d_host2dev_async`: copy `rows` sequences of `bytes`,
    /// applying strides after each (scatter/gather, §2.4).
    #[allow(clippy::too_many_arguments)]
    pub fn memcpy2d_host2dev_async(
        &mut self,
        accel: &mut Accel,
        dev: u32,
        host_va: u64,
        bytes: u32,
        rows: u32,
        dev_stride: u32,
        host_stride: u32,
    ) -> Result<TransferId> {
        self.start(accel, DmaDir::HostToDev, dev, host_va, bytes, rows, dev_stride, host_stride, false)
    }

    /// `hero_memcpy2d_dev2host_async`.
    #[allow(clippy::too_many_arguments)]
    pub fn memcpy2d_dev2host_async(
        &mut self,
        accel: &mut Accel,
        host_va: u64,
        dev: u32,
        bytes: u32,
        rows: u32,
        dev_stride: u32,
        host_stride: u32,
    ) -> Result<TransferId> {
        self.start(accel, DmaDir::DevToHost, dev, host_va, bytes, rows, dev_stride, host_stride, false)
    }

    /// Blocking 1D host→device copy (no `_async` suffix): returns after all
    /// data is transferred (the simulator clock advances past completion).
    pub fn memcpy_host2dev(
        &mut self,
        accel: &mut Accel,
        dev: u32,
        host_va: u64,
        bytes: u32,
    ) -> Result<()> {
        let id = self.memcpy_host2dev_async(accel, dev, host_va, bytes)?;
        self.wait(accel, id)
    }

    /// Blocking 1D device→host copy.
    pub fn memcpy_dev2host(
        &mut self,
        accel: &mut Accel,
        host_va: u64,
        dev: u32,
        bytes: u32,
    ) -> Result<()> {
        let id = self.memcpy_dev2host_async(accel, host_va, dev, bytes)?;
        self.wait(accel, id)
    }

    /// `hero_memcpy_wait`: advance simulated time to transfer completion.
    pub fn wait(&mut self, accel: &mut Accel, id: TransferId) -> Result<()> {
        let done = accel.clusters[id.cluster]
            .dma
            .completion(id.id)
            .ok_or_else(|| anyhow!("unknown transfer id {:?}", id))?;
        if done > accel.now {
            accel.now = done;
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn start(
        &mut self,
        accel: &mut Accel,
        dir: DmaDir,
        dev: u32,
        host_va: u64,
        bytes: u32,
        rows: u32,
        dev_stride: u32,
        host_stride: u32,
        merged: bool,
    ) -> Result<TransferId> {
        // Which cluster's engine? The one owning the device address (L2
        // traffic uses cluster 0's engine in this model).
        let cluster = match map::decode(
            dev,
            accel.clusters.len(),
            accel.cfg.accel.l1_bytes as u32,
            accel.cfg.accel.l2_bytes as u32,
        ) {
            map::Region::Tcdm(cl, _) => cl,
            map::Region::L2(_) => 0,
            map::Region::Unmapped => bail!("DMA to unmapped device address {dev:#010x}"),
        };
        let d = Descriptor {
            dir,
            dev_addr: dev,
            host_va,
            row_bytes: bytes,
            rows,
            dev_stride,
            host_stride,
            merged,
        };
        let id = accel.dma_submit_external(cluster, &d)?;
        Ok(TransferId { cluster, id })
    }
}

fn store_dev(accel: &mut Accel, addr: u32, v: u32) {
    match map::decode(
        addr,
        accel.clusters.len(),
        accel.cfg.accel.l1_bytes as u32,
        accel.cfg.accel.l2_bytes as u32,
    ) {
        map::Region::Tcdm(cl, off) => accel.clusters[cl].tcdm.mem.store(off, v),
        map::Region::L2(off) => accel.l2.store(off, v),
        map::Region::Unmapped => panic!("store to unmapped device address {addr:#010x}"),
    }
}

fn load_dev(accel: &Accel, addr: u32) -> u32 {
    match map::decode(
        addr,
        accel.clusters.len(),
        accel.cfg.accel.l1_bytes as u32,
        accel.cfg.accel.l2_bytes as u32,
    ) {
        map::Region::Tcdm(cl, off) => accel.clusters[cl].tcdm.mem.load(off),
        map::Region::L2(off) => accel.l2.load(off),
        map::Region::Unmapped => panic!("load from unmapped device address {addr:#010x}"),
    }
}

/// Performance-measurement API (§2.4): dynamically allocate a hardware
/// counter for an event; pause/continue all with single-cycle overhead.
pub struct PerfSession {
    events: Vec<Event>,
    base: PerfCounters,
    max_counters: usize,
}

impl PerfSession {
    pub fn new(accel: &Accel) -> Self {
        PerfSession { events: Vec::new(), base: accel.perf_aggregate(), max_counters: 8 }
    }

    /// `hero_perf_alloc`: returns an error when the hardware counters are
    /// exhausted (8 event counters per core on CV32E40P-style PMUs).
    pub fn alloc(&mut self, ev: Event) -> Result<usize> {
        if self.events.len() >= self.max_counters {
            bail!("hardware performance counters exhausted");
        }
        self.events.push(ev);
        Ok(self.events.len() - 1)
    }

    /// `hero_perf_continue_all`: (re)start counting from here.
    pub fn continue_all(&mut self, accel: &mut Accel) {
        self.base = accel.perf_aggregate();
        for cl in &mut accel.clusters {
            for c in &mut cl.cores {
                c.perf.running = true;
            }
        }
    }

    /// `hero_perf_pause_all`.
    pub fn pause_all(&self, accel: &mut Accel) {
        for cl in &mut accel.clusters {
            for c in &mut cl.cores {
                c.perf.running = false;
            }
        }
    }

    /// Read an allocated counter (delta since the last `continue_all`).
    pub fn read(&self, accel: &Accel, handle: usize) -> u64 {
        let ev = self.events[handle];
        accel.perf_aggregate().get(ev).saturating_sub(self.base.get(ev))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::aurora;
    use crate::host::HostContext;

    fn setup() -> (Accel, HostContext, HeroApi) {
        let mut accel = Accel::new(aurora(), 1 << 20);
        let host = HostContext::new();
        let api = HeroApi::new(&accel);
        // The API drives DMA without an offload; activate cluster 0.
        accel
            .load_program(
                std::sync::Arc::new(crate::isa::Program::new(vec![crate::isa::Inst::Halt])),
                1,
            )
            .unwrap();
        (accel, host, api)
    }

    #[test]
    fn l1_malloc_free_capacity() {
        let (mut accel, _, mut api) = setup();
        let cap0 = api.capacity(SpmLevel::L1(0));
        assert_eq!(cap0, 128 * 1024 - 128 * 1024 / 8); // 112 KiB user L1
        let a = api.malloc(&mut accel, SpmLevel::L1(0), 1024).unwrap();
        assert!(api.capacity(SpmLevel::L1(0)) < cap0);
        assert_eq!(api.free(&mut accel, SpmLevel::L1(0), a), o1heap::FreeResult::Ok);
        assert_eq!(api.capacity(SpmLevel::L1(0)), cap0);
    }

    #[test]
    fn canary_detects_kernel_overflow() {
        let (mut accel, _, mut api) = setup();
        let a = api.malloc(&mut accel, SpmLevel::L1(0), 64).unwrap();
        // A buggy "kernel" writes one word past the end.
        store_dev(&mut accel, a + 64, 0xbad);
        assert_eq!(
            api.free(&mut accel, SpmLevel::L1(0), a),
            o1heap::FreeResult::CanaryCorrupted
        );
    }

    #[test]
    fn l2_malloc_works() {
        let (mut accel, _, mut api) = setup();
        let a = api.malloc(&mut accel, SpmLevel::L2, 4096).unwrap();
        assert!(a >= map::L2_BASE);
        assert_eq!(api.free(&mut accel, SpmLevel::L2, a), o1heap::FreeResult::Ok);
    }

    #[test]
    fn memcpy_roundtrip_1d() {
        let (mut accel, mut host, mut api) = setup();
        let buf = host.alloc(&mut accel, 64).unwrap();
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        host.write_f32(&mut accel, &buf, &data);
        let dev = api.malloc(&mut accel, SpmLevel::L1(0), 256).unwrap();
        api.memcpy_host2dev(&mut accel, dev, buf.va, 256).unwrap();
        // Scale on "device" then copy back.
        for i in 0..64 {
            let v = load_dev(&accel, dev + i * 4);
            store_dev(&mut accel, dev + i * 4, (f32::from_bits(v) * 2.0).to_bits());
        }
        let out = host.alloc(&mut accel, 64).unwrap();
        api.memcpy_dev2host(&mut accel, out.va, dev, 256).unwrap();
        let got = host.read_f32(&accel, &out);
        for i in 0..64 {
            assert_eq!(got[i], 2.0 * i as f32);
        }
    }

    #[test]
    fn memcpy2d_gathers() {
        let (mut accel, mut host, mut api) = setup();
        let buf = host.alloc(&mut accel, 64).unwrap(); // 8x8 matrix
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        host.write_f32(&mut accel, &buf, &data);
        let dev = api.malloc(&mut accel, SpmLevel::L1(0), 64).unwrap();
        // Gather a 4x4 tile at (2,3): 4 rows of 16 B, host stride 32 B.
        let id = api
            .memcpy2d_host2dev_async(&mut accel, dev, buf.va + (2 * 8 + 3) * 4, 16, 4, 16, 32)
            .unwrap();
        api.wait(&mut accel, id).unwrap();
        for r in 0..4u32 {
            for c in 0..4u32 {
                let v = f32::from_bits(load_dev(&accel, dev + (r * 4 + c) * 4));
                assert_eq!(v, ((r + 2) * 8 + c + 3) as f32);
            }
        }
    }

    #[test]
    fn perf_session_counts_and_exhausts() {
        let (accel, _, _) = setup();
        let mut sess = PerfSession::new(&accel);
        for _ in 0..8 {
            sess.alloc(Event::Cycles).unwrap();
        }
        assert!(sess.alloc(Event::Instructions).is_err());
    }
}
