//! OpenMP offloading runtime (§2.3).
//!
//! "A heterogeneous application starts executing on the host. When the host
//! encounters a `#pragma omp target` directive, it offloads the code within
//! the target region to the specified accelerator. ... The plugin passes a
//! pointer to the offloaded code and data to a hardware mailbox in the
//! device, thereby starting execution on the device."
//!
//! With unified virtual memory enabled (the default), pointers are passed
//! unmodified and no data is copied — offloading does *not* copy data into
//! the SPMs (§2.3 gives the two reasons: coarse-grained offload model, and
//! `map` clauses cannot express tiling).

use crate::accel::Accel;
use crate::compiler::Lowered;
use crate::host::HostBuf;
use crate::trace::{Event, PerfCounters};
use anyhow::Result;

/// Result of one offload.
#[derive(Debug, Clone)]
pub struct OffloadResult {
    /// Device cycles from offload-manager wakeup to completion.
    pub device_cycles: u64,
    /// End-to-end cycles as the host observes them (device + mailbox +
    /// driver overheads) — what the paper's timestamps measure (§3).
    pub total_cycles: u64,
    /// Aggregated device performance counters for this offload.
    pub perf: PerfCounters,
}

impl OffloadResult {
    /// Cycles attributable to DMA (core-visible wait + descriptor setup),
    /// as plotted on the right-hand scales of Figs 4/5 and in Fig 8.
    pub fn dma_cycles(&self) -> u64 {
        self.perf.get(Event::DmaWaitCycles)
            + self.perf.get(Event::DmaTransfers) * 30 // setup stalls
    }
}

/// Execute one `target` region: marshal `map`-clause pointers, ring the
/// mailbox, run the device until the offload manager reports completion.
///
/// `bufs` must match `lowered.arrays` order; `fargs` matches
/// `lowered.floats`. `n_teams` clusters participate (OpenMP `num_teams`).
///
/// This is a thin layer over the shared offload core
/// ([`crate::session::core::offload_lowered`]) — the same marshal/run path
/// [`crate::session::Session`] and the scheduler use, so offload semantics
/// exist exactly once.
pub fn offload(
    accel: &mut Accel,
    lowered: &Lowered,
    bufs: &[&HostBuf],
    fargs: &[f32],
    n_teams: usize,
    max_cycles: u64,
) -> Result<OffloadResult> {
    crate::session::core::offload_lowered(accel, lowered, bufs, fargs, n_teams, max_cycles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, ir::*, LowerOpts};
    use crate::config::aurora;
    use crate::host::HostContext;

    /// y[i] = a*x[i] + y[i], untiled (all accesses remote).
    fn saxpy(n: i32) -> Kernel {
        let mut b = KernelBuilder::new("saxpy");
        let x = b.host_array("X", vec![ci(n)]);
        let y = b.host_array("Y", vec![ci(n)]);
        let _n = b.const_param("N", n);
        let a = b.float_param("a");
        let i = b.loop_var("i");
        b.body(vec![par_for(
            i,
            ci(0),
            ci(n),
            vec![st(
                y,
                vec![var(i)],
                var(a).mul(ld(x, vec![var(i)])).add(ld(y, vec![var(i)])),
            )],
        )])
    }

    #[test]
    fn saxpy_offload_end_to_end() {
        let cfg = aurora();
        let (lowered, _) = compile(&saxpy(256), &LowerOpts::for_config(&cfg), None).unwrap();
        let mut accel = Accel::new(cfg, 1 << 20);
        let mut host = HostContext::new();
        let xb = host.alloc(&mut accel, 256).unwrap();
        let yb = host.alloc(&mut accel, 256).unwrap();
        let xs: Vec<f32> = (0..256).map(|i| i as f32).collect();
        let ys: Vec<f32> = (0..256).map(|i| 2.0 * i as f32).collect();
        host.write_f32(&mut accel, &xb, &xs);
        host.write_f32(&mut accel, &yb, &ys);
        let res = offload(&mut accel, &lowered, &[&xb, &yb], &[3.0], 1, 10_000_000).unwrap();
        let got = host.read_f32(&accel, &yb);
        for i in 0..256 {
            assert_eq!(got[i], 3.0 * i as f32 + 2.0 * i as f32, "y[{i}]");
        }
        assert!(res.total_cycles > res.device_cycles);
        assert!(res.perf.get(Event::RemoteAccess) >= 512, "saxpy is remote");
    }

    #[test]
    fn wrong_arity_rejected() {
        let cfg = aurora();
        let (lowered, _) = compile(&saxpy(16), &LowerOpts::for_config(&cfg), None).unwrap();
        let mut accel = Accel::new(cfg, 1 << 20);
        let mut host = HostContext::new();
        let xb = host.alloc(&mut accel, 16).unwrap();
        assert!(offload(&mut accel, &lowered, &[&xb], &[1.0], 1, 1_000_000).is_err());
    }
}
