//! Runtime libraries (§2.3, §2.4): OpenMP-style offloading, the HERO API,
//! and the PJRT bridge to the AOT-compiled JAX/Pallas artifacts.

pub mod hero_api;
pub mod omp;
pub mod pjrt;

pub use omp::{offload, OffloadResult};
