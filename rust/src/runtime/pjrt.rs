//! PJRT bridge: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! Layer 2/1 of the stack live in `python/compile`: JAX kernel graphs
//! calling Pallas kernels, lowered **once** at build time (`make artifacts`)
//! to HLO *text* (see `python/compile/aot.py` — text, not serialized protos:
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids).
//!
//! At run time this module loads `artifacts/<kernel>.hlo.txt`, compiles each
//! once on the PJRT CPU client, caches the executable, and runs it — the
//! golden functional model every simulated offload is verified against.
//! Python never runs on this path.
//!
//! ## Graceful degradation
//!
//! The PJRT backend depends on the `xla` bindings, which need a native
//! libxla install. That dependency is gated behind the `pjrt-xla` cargo
//! feature so a clean checkout builds and tests without it. Without the
//! feature (or without built artifacts) every golden-model check *skips
//! with a warning* instead of erroring: [`PjrtRuntime::new`] still
//! succeeds, [`PjrtRuntime::available`] reports `false`, and
//! `bench_harness::verify_pjrt` returns `Ok(false)`. The host golden model
//! (`Workload::golden`) remains the mandatory check either way.

use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

#[cfg(feature = "pjrt-xla")]
mod backend {
    //! The real PJRT CPU client (feature `pjrt-xla`).
    use anyhow::{anyhow, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    /// A named, compiled artifact.
    struct Artifact {
        exe: xla::PjRtLoadedExecutable,
    }

    pub struct Backend {
        client: xla::PjRtClient,
        dir: PathBuf,
        cache: HashMap<String, Artifact>,
    }

    impl Backend {
        pub fn new(dir: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
            Ok(Backend { client, dir: dir.to_path_buf(), cache: HashMap::new() })
        }

        fn path(&self, name: &str) -> PathBuf {
            self.dir.join(format!("{name}.hlo.txt"))
        }

        pub fn available(&self, name: &str) -> bool {
            self.path(name).exists()
        }

        fn load(&mut self, name: &str) -> Result<&Artifact> {
            if !self.cache.contains_key(name) {
                let path = self.path(name);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("bad path"))?,
                )
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
                self.cache.insert(name.to_string(), Artifact { exe });
            }
            Ok(self.cache.get(name).unwrap())
        }

        /// Execute artifact `name`; artifacts are lowered with
        /// `return_tuple=True`, outputs are unpacked from the tuple.
        pub fn exec_f32(
            &mut self,
            name: &str,
            inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<Vec<f32>>> {
            // Build literals first (cache borrow rules).
            let mut lits: Vec<xla::Literal> = Vec::with_capacity(inputs.len());
            for (data, shape) in inputs {
                let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape: {e:?}"))?;
                lits.push(lit);
            }
            let art = self.load(name)?;
            let result = art
                .exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e:?}"))?;
            let tuple = out.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
            tuple
                .into_iter()
                .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt-xla"))]
mod backend {
    //! Stub backend: artifacts are never available; execution is an error.
    //! Callers that probe with [`Backend::available`] first (the verify
    //! paths all do) therefore *skip* PJRT checks instead of failing.
    use anyhow::{bail, Result};
    use std::path::{Path, PathBuf};

    pub struct Backend {
        dir: PathBuf,
    }

    impl Backend {
        pub fn new(dir: &Path) -> Result<Self> {
            Ok(Backend { dir: dir.to_path_buf() })
        }

        pub fn available(&self, name: &str) -> bool {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            if path.exists() {
                eprintln!(
                    "warning: PJRT artifact {} exists but this build lacks the \
                     `pjrt-xla` feature; skipping the PJRT golden-model check",
                    path.display()
                );
            }
            false
        }

        pub fn exec_f32(
            &mut self,
            name: &str,
            _inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<Vec<f32>>> {
            bail!(
                "PJRT backend not compiled in (artifact {name:?}); add the xla \
                 bindings and rebuild (`cargo add xla && cargo build --features \
                 pjrt-xla`) to execute AOT artifacts"
            )
        }
    }
}

/// The PJRT runtime: client + executable cache (or the graceful stub when
/// built without the `pjrt-xla` feature).
pub struct PjrtRuntime {
    backend: backend::Backend,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client over an artifact directory. Never fails in
    /// stub builds; with `pjrt-xla` it fails when no PJRT plugin loads.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        Ok(PjrtRuntime { backend: backend::Backend::new(dir.as_ref())? })
    }

    /// The default artifact directory (repo `artifacts/`), honoring
    /// `HERO_ARTIFACTS` for out-of-tree runs.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("HERO_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Whether an artifact exists *and* this build can execute it (benches
    /// skip PJRT verification otherwise).
    pub fn available(&self, name: &str) -> bool {
        self.backend.available(name)
    }

    /// Execute artifact `name` on f32 inputs with the given shapes; returns
    /// the flattened f32 outputs (one vec per tuple element).
    pub fn exec_f32(&mut self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        for (data, shape) in inputs {
            let n: usize = shape.iter().product();
            if n != data.len() {
                bail!("shape {:?} does not match {} elements", shape, data.len());
            }
        }
        self.backend.exec_f32(name, inputs)
    }

    /// Convenience: single-output execution.
    pub fn exec_f32_single(
        &mut self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<f32>> {
        let mut outs = self.exec_f32(name, inputs)?;
        if outs.len() != 1 {
            bail!("{name} returned {} outputs, expected 1", outs.len());
        }
        Ok(outs.pop().unwrap())
    }
}

/// Compare simulated output with the PJRT golden model.
pub fn assert_allclose(got: &[f32], want: &[f32], rtol: f32, atol: f32) -> Result<()> {
    if got.len() != want.len() {
        bail!("length mismatch: {} vs {}", got.len(), want.len());
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        if (g - w).abs() > tol {
            bail!("mismatch at [{i}]: got {g}, want {w} (tol {tol})");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allclose_checks() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6).is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-5, 1e-6).is_err());
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1e-5, 1e-6).is_err());
    }

    /// A clean checkout (no artifacts, no pjrt-xla feature) must construct a
    /// runtime and report artifacts as unavailable instead of erroring —
    /// this is what lets `cargo test -q` pass without the Python AOT step.
    #[test]
    fn degrades_gracefully_without_artifacts() {
        let rt = match PjrtRuntime::new("artifacts-nonexistent-dir") {
            Ok(rt) => rt,
            Err(_) => return, // pjrt-xla build without a PJRT plugin: fine
        };
        assert!(!rt.available("smoke_matmul2"));
    }

    /// Full PJRT round trip — runs only when `make artifacts` has produced
    /// the smoke artifact and the `pjrt-xla` feature is enabled.
    #[test]
    fn smoke_artifact_runs_if_built() {
        let mut rt = match PjrtRuntime::new(PjrtRuntime::default_dir()) {
            Ok(rt) => rt,
            Err(_) => return, // no PJRT plugin in this environment
        };
        if !rt.available("smoke_matmul2") {
            return; // artifacts not built yet (or stub backend)
        }
        let x = [1f32, 2., 3., 4.];
        let y = [1f32, 1., 1., 1.];
        let out = rt
            .exec_f32_single("smoke_matmul2", &[(&x, &[2, 2]), (&y, &[2, 2])])
            .unwrap();
        assert_eq!(out, vec![5., 5., 9., 9.]);
    }
}
