//! Performance monitoring: hardware event counters and cycle accounting.
//!
//! Models the paper's §2.4 performance-measurement API substrate: a set of
//! hardware events, a small number of physical counters to which events are
//! assigned dynamically (`hero_perf_alloc`), and pause/continue controls with
//! single-cycle overhead. The simulator additionally keeps *all* events in a
//! [`PerfCounters`] block per core/cluster, which the figure-regeneration
//! benches read directly.

/// Hardware events observable on the accelerator (§2.4: "from monotonic
/// clock cycles over memory accesses and stalls to memory and interconnect
/// contention and utilization metrics").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum Event {
    /// Monotonic clock cycles while the counter is running.
    Cycles,
    /// Retired instructions.
    Instructions,
    /// TCDM (L1 SPM) accesses.
    TcdmAccess,
    /// TCDM bank-conflict stall cycles.
    TcdmConflict,
    /// L2 SPM accesses.
    L2Access,
    /// Remote (host address space) accesses from a core.
    RemoteAccess,
    /// Load/store stall cycles (memory latency).
    LoadStall,
    /// Instruction-fetch stall cycles (icache miss/refill).
    IFetchStall,
    /// Shared-icache misses.
    IcacheMiss,
    /// L0 loop-buffer hits.
    L0Hit,
    /// Taken branches.
    BranchTaken,
    /// Hardware-loop back-edges (zero-cycle).
    HwLoop,
    /// IOMMU TLB hits.
    TlbHit,
    /// IOMMU TLB misses.
    TlbMiss,
    /// Cycles a core spent waiting on DMA completion (`hero_memcpy_wait`
    /// and blocking transfers).
    DmaWaitCycles,
    /// Cycles the DMA engine was busy moving data.
    DmaBusyCycles,
    /// Bytes moved by the DMA engine.
    DmaBytes,
    /// DMA transfer descriptors programmed.
    DmaTransfers,
    /// Individual bursts issued by the DMA engine (a 2D transfer issues one
    /// per row unless rows are merged).
    DmaBursts,
    /// Barrier synchronizations.
    Barrier,
    /// Cycles stalled at barriers.
    BarrierStall,
    /// Extra cycles DMA transfers waited on the shared carrier-board DRAM
    /// beyond their uncontended service time (bandwidth contention at the
    /// DRAM boundary; disjoint from `DmaBusyCycles` by construction).
    DmaDramStall,
}

/// Number of distinct events.
pub const N_EVENTS: usize = Event::DmaDramStall as usize + 1;

/// All events, for iteration.
pub const ALL_EVENTS: [Event; N_EVENTS] = [
    Event::Cycles,
    Event::Instructions,
    Event::TcdmAccess,
    Event::TcdmConflict,
    Event::L2Access,
    Event::RemoteAccess,
    Event::LoadStall,
    Event::IFetchStall,
    Event::IcacheMiss,
    Event::L0Hit,
    Event::BranchTaken,
    Event::HwLoop,
    Event::TlbHit,
    Event::TlbMiss,
    Event::DmaWaitCycles,
    Event::DmaBusyCycles,
    Event::DmaBytes,
    Event::DmaTransfers,
    Event::DmaBursts,
    Event::Barrier,
    Event::BarrierStall,
    Event::DmaDramStall,
];

impl Event {
    /// Short mnemonic, as printed by `hero info --events`.
    pub fn name(&self) -> &'static str {
        match self {
            Event::Cycles => "cycles",
            Event::Instructions => "instr",
            Event::TcdmAccess => "tcdm_access",
            Event::TcdmConflict => "tcdm_conflict",
            Event::L2Access => "l2_access",
            Event::RemoteAccess => "remote_access",
            Event::LoadStall => "load_stall",
            Event::IFetchStall => "ifetch_stall",
            Event::IcacheMiss => "icache_miss",
            Event::L0Hit => "l0_hit",
            Event::BranchTaken => "branch_taken",
            Event::HwLoop => "hwloop",
            Event::TlbHit => "tlb_hit",
            Event::TlbMiss => "tlb_miss",
            Event::DmaWaitCycles => "dma_wait_cycles",
            Event::DmaBusyCycles => "dma_busy_cycles",
            Event::DmaBytes => "dma_bytes",
            Event::DmaTransfers => "dma_transfers",
            Event::DmaBursts => "dma_bursts",
            Event::Barrier => "barrier",
            Event::BarrierStall => "barrier_stall",
            Event::DmaDramStall => "dma_dram_stall",
        }
    }
}

/// A block of event counters (one per core in the simulator; aggregated
/// views are produced by [`PerfCounters::merge`]).
#[derive(Debug, Clone)]
pub struct PerfCounters {
    counts: [u64; N_EVENTS],
    /// Whether counting is active (hero_perf_pause_all / continue_all).
    pub running: bool,
}

impl Default for PerfCounters {
    fn default() -> Self {
        Self::new()
    }
}

impl PerfCounters {
    pub fn new() -> Self {
        PerfCounters { counts: [0; N_EVENTS], running: true }
    }

    /// Add `n` to an event counter (no-op while paused).
    #[inline(always)]
    pub fn add(&mut self, ev: Event, n: u64) {
        if self.running {
            self.counts[ev as usize] += n;
        }
    }

    /// Increment an event counter by one (no-op while paused).
    #[inline(always)]
    pub fn bump(&mut self, ev: Event) {
        self.add(ev, 1);
    }

    /// Read a counter.
    #[inline]
    pub fn get(&self, ev: Event) -> u64 {
        self.counts[ev as usize]
    }

    /// Reset all counters.
    pub fn reset(&mut self) {
        self.counts = [0; N_EVENTS];
    }

    /// Merge another counter block into this one (sums all events).
    pub fn merge(&mut self, other: &PerfCounters) {
        for i in 0..N_EVENTS {
            self.counts[i] += other.counts[i];
        }
    }

    /// Cycles attributable to DMA: core-visible waits plus a 2-cycle
    /// descriptor-setup charge per transfer. The single attribution model
    /// behind `RunOutcome::dma_cycles` and `LaunchResult::dma_cycles`, so
    /// the dma/compute split agrees across every front door.
    pub fn dma_attributed_cycles(&self) -> u64 {
        self.get(Event::DmaWaitCycles) + self.get(Event::DmaTransfers) * 2
    }

    /// Subtract a snapshot (for per-offload deltas).
    pub fn sub(&mut self, other: &PerfCounters) {
        for i in 0..N_EVENTS {
            self.counts[i] = self.counts[i].saturating_sub(other.counts[i]);
        }
    }

    /// Render a compact multi-line report of all non-zero counters.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for ev in ALL_EVENTS {
            let v = self.get(ev);
            if v != 0 {
                out.push_str(&format!("{:>16}: {v}\n", ev.name()));
            }
        }
        out
    }
}

/// Scheduler-level events (the `sched` subsystem's analogue of the device
/// perf events above): the life cycle of an offload job from submission
/// through dispatch to completion, time-stamped in simulated cycles.
/// Rendered by `hero serve --trace`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SchedEvent {
    /// Job entered the queue, with its QoS class
    /// ([`crate::sched::Priority`]).
    Submitted { job: usize, priority: crate::sched::Priority },
    /// Job was refused (admission control, unknown kernel, compile error).
    Rejected { job: usize, reason: String },
    /// Oversized job decomposed into feasible sub-jobs (capacity policy).
    Split { job: usize, children: Vec<usize> },
    /// The last unsettled dataflow producer of a queued job settled: the
    /// job is now ready for dispatch, and `at` is its *effective arrival*
    /// — the latest of its producers' finish cycles and its own declared
    /// arrival (cross-launch dependency tracking — see
    /// [`crate::sched::job::PayloadSrc`]).
    DependencyReady { job: usize, producer: usize, at: u64 },
    /// Dispatch had to lower the kernel (binary cache miss): `cycles` of
    /// simulated compile time were charged to the job's instance.
    CompileMiss { job: usize, cycles: u64 },
    /// Dispatch reused a cached binary.
    CompileHit { job: usize },
    /// Job (plus `batched` same-binary followers) started on an instance.
    Dispatched { job: usize, instance: usize, start: u64, batched: usize },
    /// Job finished on its instance at simulated cycle `end`; `dram_stall`
    /// cycles of its occupancy were contention waits on the shared
    /// carrier-board DRAM.
    Completed { job: usize, instance: usize, end: u64, dram_stall: u64 },
    /// A job's shared-virtual-memory operands were served: `mode` is the
    /// strategy actually taken (`auto` resolves to `pin` or `copy` before
    /// this is recorded), `cycles` the full SVM charge added to the job's
    /// occupancy, and `hits`/`misses` the board TLB traffic (both 0 for a
    /// copy, which bypasses the TLB — see [`crate::svm`]).
    SvmResolved { job: usize, mode: &'static str, cycles: u64, hits: u64, misses: u64 },
    /// A queued-but-assigned batch follower was displaced back into the
    /// queue by an arrived High-priority job (`by`), at the cycle the
    /// follower would otherwise have started (`at`). Displacement happens
    /// strictly between member executions — never mid-kernel — so it moves
    /// time, not numerics (preemption — see
    /// `crate::sched::Scheduler::with_preemption`).
    Preempted { job: usize, by: usize, at: u64 },
    /// An autotuned dispatch ran the AutoDMA knob search for a key it had
    /// not seen (memo hits are silent): `variant` is the chosen recipe's
    /// label, `candidates` the surviving search-space size, and
    /// `predicted`/`default_predicted` the chosen and default-recipe cycle
    /// scores (see [`crate::sched::tune`]). Untimed — tuning is host-side
    /// work, like compilation.
    Tuned {
        job: usize,
        variant: String,
        candidates: usize,
        predicted: u64,
        default_predicted: u64,
    },
    /// The job's offload faulted on its instance at cycle `at` (the end of
    /// the occupancy window the attempt still consumed): `kind` is the
    /// [`crate::fault::FaultKind::label`] — injected `transient`/`timeout`
    /// faults or a detected watchdog `deadline` overrun.
    Faulted { job: usize, instance: usize, kind: &'static str, at: u64 },
    /// A faulted job re-entered the queue for retry `attempt` (1-based),
    /// eligible for dispatch no earlier than cycle `at` (exponential
    /// backoff — see [`crate::fault::backoff_cycles`]).
    Retried { job: usize, attempt: u32, at: u64 },
    /// A fleet board went unhealthy at cycle `at` ([`crate::fault::BoardFault`]):
    /// its queued jobs are evacuated to surviving boards.
    BoardDown { board: usize, at: u64 },
    /// A failed fleet board recovered at cycle `at` and rejoined routing.
    BoardUp { board: usize, at: u64 },
    /// A queued job was evacuated off unhealthy board `from` and
    /// resubmitted on board `to` at cycle `at` (recorded on the source
    /// board's trace; `job` is the source board's job id).
    Migrated { job: usize, from: usize, to: usize, at: u64 },
}

impl SchedEvent {
    /// The simulated cycle this event is stamped with, when it carries one.
    /// Submission/rejection/compile events are untimed (they happen in host
    /// order, not board time). Used by the fleet renderer to interleave
    /// per-board traces on a merged timeline ([`crate::fleet`]).
    pub fn cycle(&self) -> Option<u64> {
        match self {
            SchedEvent::Dispatched { start, .. } => Some(*start),
            SchedEvent::Completed { end, .. } => Some(*end),
            SchedEvent::DependencyReady { at, .. } => Some(*at),
            SchedEvent::Preempted { at, .. } => Some(*at),
            SchedEvent::Faulted { at, .. } => Some(*at),
            SchedEvent::Retried { at, .. } => Some(*at),
            SchedEvent::BoardDown { at, .. } => Some(*at),
            SchedEvent::BoardUp { at, .. } => Some(*at),
            SchedEvent::Migrated { at, .. } => Some(*at),
            _ => None,
        }
    }

    /// Render this event as the one-line form `hero serve --trace` prints.
    /// Shared by [`SchedTrace::render`] (single board) and the fleet's
    /// board-prefixed merged rendering, so the two never drift.
    pub fn render_line(&self) -> String {
        match self {
            SchedEvent::Submitted { job, priority } => {
                if priority.is_high() {
                    format!("submit    job {job} [high]")
                } else {
                    format!("submit    job {job}")
                }
            }
            SchedEvent::Rejected { job, reason } => format!("reject    job {job}: {reason}"),
            SchedEvent::Split { job, children } => {
                format!("split     job {job} -> {children:?}")
            }
            SchedEvent::DependencyReady { job, producer, at } => format!(
                "ready     job {job} (producer {producer} settled; effective arrival \
                 cycle {at})"
            ),
            SchedEvent::CompileMiss { job, cycles } => {
                format!("compile   job {job} (miss, {cycles} cy)")
            }
            SchedEvent::CompileHit { job } => format!("compile   job {job} (cache hit)"),
            SchedEvent::Dispatched { job, instance, start, batched } => format!(
                "dispatch  job {job} -> instance {instance} at cycle {start} (+{batched} batched)"
            ),
            SchedEvent::Completed { job, instance, end, dram_stall } => {
                if *dram_stall > 0 {
                    format!(
                        "complete  job {job} on instance {instance} at cycle {end} \
                         ({dram_stall} cy DRAM stall)"
                    )
                } else {
                    format!("complete  job {job} on instance {instance} at cycle {end}")
                }
            }
            SchedEvent::SvmResolved { job, mode, cycles, hits, misses } => format!(
                "svm       job {job} ({mode}: {cycles} cy, {hits} hit(s), {misses} miss(es))"
            ),
            SchedEvent::Preempted { job, by, at } => {
                format!("preempt   job {job} displaced by job {by} at cycle {at}")
            }
            SchedEvent::Tuned { job, variant, candidates, predicted, default_predicted } => {
                format!(
                    "tune      job {job} -> {variant} ({candidates} candidate(s), \
                     predicted {predicted} cy vs default {default_predicted})"
                )
            }
            SchedEvent::Faulted { job, instance, kind, at } => {
                format!("fault     job {job} on instance {instance} at cycle {at} ({kind})")
            }
            SchedEvent::Retried { job, attempt, at } => {
                format!("retry     job {job} (attempt {attempt}, not before cycle {at})")
            }
            SchedEvent::BoardDown { board, at } => {
                format!("down      board {board} unhealthy at cycle {at}")
            }
            SchedEvent::BoardUp { board, at } => {
                format!("up        board {board} recovered at cycle {at}")
            }
            SchedEvent::Migrated { job, from, to, at } => {
                format!("migrate   job {job} board {from} -> board {to} at cycle {at}")
            }
        }
    }
}

/// An append-only scheduler event log.
#[derive(Debug, Default)]
pub struct SchedTrace {
    pub events: Vec<SchedEvent>,
}

impl SchedTrace {
    pub fn new() -> Self {
        SchedTrace::default()
    }

    pub fn record(&mut self, e: SchedEvent) {
        self.events.push(e);
    }

    /// Jobs the trace saw dispatched, in dispatch order.
    pub fn dispatch_order(&self) -> Vec<usize> {
        self.events
            .iter()
            .filter_map(|e| match e {
                SchedEvent::Dispatched { job, .. } => Some(*job),
                _ => None,
            })
            .collect()
    }

    /// Render one line per event.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for e in &self.events {
            out.push_str(&e.render_line());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sched_trace_records_and_renders() {
        use crate::sched::Priority;
        let mut t = SchedTrace::new();
        t.record(SchedEvent::Submitted { job: 0, priority: Priority::Normal });
        t.record(SchedEvent::Submitted { job: 1, priority: Priority::High });
        t.record(SchedEvent::CompileMiss { job: 0, cycles: 1000 });
        t.record(SchedEvent::Dispatched { job: 0, instance: 1, start: 0, batched: 2 });
        t.record(SchedEvent::Completed { job: 0, instance: 1, end: 500, dram_stall: 40 });
        t.record(SchedEvent::DependencyReady { job: 1, producer: 0, at: 500 });
        assert_eq!(t.dispatch_order(), vec![0]);
        let s = t.render();
        assert!(s.contains("submit    job 0\n"), "normal submits carry no marker: {s}");
        assert!(s.contains("submit    job 1 [high]"), "priority surfaces in the log: {s}");
        assert!(s.contains("dispatch  job 0 -> instance 1"));
        assert!(s.contains("cache") || s.contains("miss"));
        assert!(s.contains("ready     job 1"), "dataflow readiness surfaces in the log: {s}");
        assert_eq!(s.lines().count(), 6);
    }

    #[test]
    fn svm_events_render_mode_and_tlb_traffic() {
        let mut t = SchedTrace::new();
        t.record(SchedEvent::SvmResolved { job: 7, mode: "pin", cycles: 342, hits: 0, misses: 1 });
        t.record(SchedEvent::SvmResolved { job: 8, mode: "copy", cycles: 308, hits: 0, misses: 0 });
        let s = t.render();
        assert!(s.contains("svm       job 7 (pin: 342 cy, 0 hit(s), 1 miss(es))"), "{s}");
        assert!(s.contains("svm       job 8 (copy: 308 cy"), "{s}");
        assert!(t.dispatch_order().is_empty(), "svm events are not dispatches");
    }

    #[test]
    fn preempt_events_render_displacer_and_cycle() {
        let mut t = SchedTrace::new();
        t.record(SchedEvent::Preempted { job: 3, by: 9, at: 4200 });
        let s = t.render();
        assert!(s.contains("preempt   job 3 displaced by job 9 at cycle 4200"), "{s}");
        assert!(t.dispatch_order().is_empty(), "preemptions are not dispatches");
    }

    #[test]
    fn tune_events_render_variant_and_scores() {
        let mut t = SchedTrace::new();
        t.record(SchedEvent::Tuned {
            job: 4,
            variant: "tile=64+db".into(),
            candidates: 7,
            predicted: 90_000,
            default_predicted: 120_000,
        });
        let s = t.render();
        assert!(
            s.contains("tune      job 4 -> tile=64+db (7 candidate(s)"),
            "{s}"
        );
        assert!(s.contains("predicted 90000 cy vs default 120000"), "{s}");
        assert!(t.dispatch_order().is_empty(), "tuning is not a dispatch");
        assert_eq!(t.events[0].cycle(), None, "tuning is host-side, untimed");
    }

    #[test]
    fn bump_and_get() {
        let mut c = PerfCounters::new();
        c.bump(Event::Cycles);
        c.add(Event::DmaBytes, 128);
        assert_eq!(c.get(Event::Cycles), 1);
        assert_eq!(c.get(Event::DmaBytes), 128);
        assert_eq!(c.get(Event::TlbMiss), 0);
    }

    #[test]
    fn pause_stops_counting() {
        let mut c = PerfCounters::new();
        c.running = false;
        c.bump(Event::Cycles);
        assert_eq!(c.get(Event::Cycles), 0);
        c.running = true;
        c.bump(Event::Cycles);
        assert_eq!(c.get(Event::Cycles), 1);
    }

    #[test]
    fn merge_sums() {
        let mut a = PerfCounters::new();
        let mut b = PerfCounters::new();
        a.add(Event::Instructions, 10);
        b.add(Event::Instructions, 5);
        a.merge(&b);
        assert_eq!(a.get(Event::Instructions), 15);
    }

    #[test]
    fn event_names_unique() {
        let mut seen = std::collections::HashSet::new();
        for ev in ALL_EVENTS {
            assert!(seen.insert(ev.name()), "duplicate name {}", ev.name());
        }
    }

    #[test]
    fn all_events_indices_match() {
        for (i, ev) in ALL_EVENTS.iter().enumerate() {
            assert_eq!(*ev as usize, i);
        }
    }
}
