//! Minimal property-testing helpers (the offline crate set has no proptest;
//! this provides deterministic random-input sweeps with case reporting).
//!
//! ```ignore
//! testkit::check(100, |rng| rng.range(1, 64), |&n| {
//!     if invariant(n) { Ok(()) } else { Err(format!("broken at {n}")) }
//! });
//! ```

/// Deterministic xorshift64* PRNG.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    pub fn f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len() - 1)]
    }
}

/// Run `cases` random property checks; panics with the failing case's debug
/// representation and seed on the first violation.
pub fn check<T: std::fmt::Debug>(
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!("property failed at case {case} with input {input:?}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.range(3, 9);
            assert!((3..=9).contains(&v));
        }
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failures() {
        check(10, |r| r.usize(60, 100), |&n| {
            if n < 50 {
                Ok(())
            } else {
                Err("boom".into())
            }
        });
    }
}
