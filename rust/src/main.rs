//! `hero` — the HEROv2 platform CLI.
//!
//! ```text
//! hero info [--resources]             platform configurations (Table 1)
//! hero run <kernel> [options]         compile + offload a workload
//!     --variant unmodified|handwritten|promoted|autodma   (default handwritten)
//!     --threads N                     OpenMP threads (default 8)
//!     --size N                        problem size (default: paper size)
//!     --config FILE                   platform config file (see config::parse)
//!     --no-xpulp                      disable Xpulpv2 codegen
//!     --verify-pjrt                   also check against the PJRT artifact
//! hero disasm <kernel> [--variant V] [--size N]   dump device assembly
//! hero autodma <kernel> [--size N]    show the AutoDMA transformation
//! hero kernels                        list workloads (Table 2)
//! hero serve [options]                drain a job stream through the
//!                                     multi-accelerator scheduler (one
//!                                     shared carrier-board DRAM)
//!     --jobs N                        synthetic jobs in the stream (default 100)
//!     --trace FILE                    replay a job trace instead of the
//!                                     synthetic stream (lines:
//!                                     `arrival kernel size [variant] [threads] [seed]`)
//!     --pool K                        accelerator instances (default 4)
//!     --policy fifo|sjf|capacity|cap-reject    dispatch policy (default fifo)
//!     --seed S                        stream seed (default 42)
//!     --board-bw B                    shared board DRAM bandwidth in
//!                                     bytes/cycle (default: config
//!                                     dram.bytes_per_cycle)
//!     --mixed-widths                  heterogeneous pool cycling 64/32/128-bit
//!                                     wide-NoC instances
//!     --no-cache                      disable the lowered-binary cache
//!     --no-batch                      disable same-binary batching
//!     --no-verify                     skip per-job golden-model checks
//!     --events                        dump the scheduler event log
//!     --config FILE                   platform config file
//! ```

use herov2::bench_harness::{self, figures, run_workload, verify, Variant};
use herov2::compiler::{self, ir, AutoDmaOpts, LowerOpts};
use herov2::config::{self, aurora, HeroConfig};
use herov2::runtime::pjrt::PjrtRuntime;
use herov2::workloads;
use std::process::exit;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("info") => cmd_info(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("disasm") => cmd_disasm(&args[1..]),
        Some("autodma") => cmd_autodma(&args[1..]),
        Some("kernels") => {
            print!("{}", figures::table2());
            0
        }
        Some("serve") => cmd_serve(&args[1..]),
        _ => {
            eprintln!("usage: hero <info|run|disasm|autodma|kernels|serve> [options]");
            2
        }
    };
    exit(code);
}

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn load_cfg(args: &[String]) -> HeroConfig {
    let mut cfg = match opt(args, "--config") {
        Some(path) => config::parse::load(&path).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            exit(2)
        }),
        None => aurora(),
    };
    if flag(args, "--no-xpulp") {
        cfg.accel.isa.xpulp = false;
    }
    cfg
}

fn pick_workload(args: &[String]) -> workloads::Workload {
    let name = args.first().cloned().unwrap_or_default();
    let size = opt(args, "--size").and_then(|s| s.parse::<usize>().ok());
    match size {
        Some(n) => workloads::build(&name, n),
        None => workloads::by_name(&name),
    }
    .unwrap_or_else(|| {
        eprintln!("unknown kernel {name:?}; see `hero kernels`");
        exit(2)
    })
}

fn pick_variant(args: &[String]) -> Variant {
    match opt(args, "--variant").as_deref() {
        None | Some("handwritten") => Variant::Handwritten,
        Some("unmodified") => Variant::Unmodified,
        Some("promoted") => Variant::Promoted,
        Some("autodma") => Variant::AutoDma,
        Some(v) => {
            eprintln!("unknown variant {v:?}");
            exit(2)
        }
    }
}

fn cmd_info(args: &[String]) -> i32 {
    print!("{}", figures::table1());
    if flag(args, "--resources") {
        use herov2::config::resources::{estimate, utilization, VU37P, ZU9EG};
        for (cfg, carrier) in [
            (aurora(), &ZU9EG),
            (config::blizzard(), &ZU9EG),
            (config::cyclone(), &VU37P),
        ] {
            let u = utilization(&cfg, carrier);
            let e = estimate(&cfg, carrier);
            println!(
                "{:<10} on {:<14}: CLB {:>5.1}%  BRAM {:>5.1}%  DSP {:>4.1}%  ~{:.0} MHz  fits={}",
                cfg.name,
                carrier.name,
                100.0 * u.clb,
                100.0 * u.bram,
                100.0 * u.dsp,
                e.freq_mhz,
                u.fits
            );
        }
    }
    0
}

fn cmd_run(args: &[String]) -> i32 {
    let w = pick_workload(args);
    let cfg = load_cfg(args);
    let variant = pick_variant(args);
    let threads: u32 = opt(args, "--threads").and_then(|s| s.parse().ok()).unwrap_or(8);
    let seed = 42;
    println!("running {} (N={}) {} with {threads} thread(s) on {}", w.name, w.size, variant.label(), cfg.name);
    let out = match run_workload(&cfg, &w, variant, threads, seed, 100_000_000_000) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("offload failed: {e}");
            return 1;
        }
    };
    if let Err(e) = verify(&w, &out, seed) {
        eprintln!("VERIFICATION FAILED: {e}");
        return 1;
    }
    println!("device cycles : {:>12}", out.result.device_cycles);
    println!("end-to-end    : {:>12} ({:.2} ms at {} MHz)", out.result.total_cycles,
        out.result.total_cycles as f64 / (cfg.accel.freq_mhz as f64 * 1e3), cfg.accel.freq_mhz);
    println!("dma cycles    : {:>12} ({:.2}%)", out.dma_cycles(),
        100.0 * out.dma_cycles() as f64 / out.cycles() as f64);
    println!("verified against the host golden model: OK");
    if let Some(r) = &out.report {
        println!("AutoDMA: tiles {:?}, remote {:?}", r.tile_sides, r.remote);
    }
    if flag(args, "--verify-pjrt") {
        let mut rt = match PjrtRuntime::new(PjrtRuntime::default_dir()) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("PJRT unavailable: {e}");
                return 1;
            }
        };
        match bench_harness::verify_pjrt(&mut rt, &w, &out, seed) {
            Ok(true) => println!("verified against the PJRT JAX/Pallas artifact: OK"),
            Ok(false) => println!("PJRT artifact {} not built (run `make artifacts`)", w.pjrt.name),
            Err(e) => {
                eprintln!("PJRT VERIFICATION FAILED: {e}");
                return 1;
            }
        }
    }
    println!("\ndevice counters:\n{}", out.result.perf.report());
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    use herov2::config::preset::with_dma_width;
    use herov2::sched::{BoardSpec, Policy, Scheduler};
    use herov2::workloads::synth;

    let cfg = load_cfg(args);
    let jobs: usize = opt(args, "--jobs").and_then(|s| s.parse().ok()).unwrap_or(100);
    let pool: usize = opt(args, "--pool").and_then(|s| s.parse().ok()).unwrap_or(4);
    let seed: u64 = opt(args, "--seed").and_then(|s| s.parse().ok()).unwrap_or(42);
    let policy_arg = opt(args, "--policy").unwrap_or_else(|| "fifo".into());
    let Some(policy) = Policy::parse(&policy_arg) else {
        eprintln!("unknown policy {policy_arg:?} (fifo|sjf|capacity|cap-reject)");
        return 2;
    };
    if pool == 0 {
        eprintln!("--pool must be at least 1");
        return 2;
    }
    // `--trace` takes a file path (PR 1's boolean event-dump flag is now
    // `--events`); catch a missing or flag-shaped value instead of silently
    // falling back to the synthetic stream.
    let trace_path = match (flag(args, "--trace"), opt(args, "--trace")) {
        (false, _) => None,
        (true, Some(path)) if !path.starts_with("--") => Some(path),
        (true, _) => {
            eprintln!(
                "--trace expects a trace file path (to dump the event log, use --events)"
            );
            return 2;
        }
    };
    let stream = match trace_path {
        Some(path) => {
            let text = match std::fs::read_to_string(&path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("cannot read trace {path:?}: {e}");
                    return 2;
                }
            };
            match synth::parse_trace(&text) {
                Ok(jobs) => {
                    println!("replaying {} jobs from trace {path}", jobs.len());
                    jobs
                }
                Err(e) => {
                    eprintln!("trace error: {e}");
                    return 2;
                }
            }
        }
        None => synth::mixed_jobs(jobs, seed),
    };
    println!(
        "serving {} jobs on {} (pool {}, policy {}, seed {seed})",
        stream.len(),
        cfg.name,
        pool,
        policy.label()
    );
    let mut sched = if flag(args, "--mixed-widths") {
        let widths = [64u32, 32, 128];
        let cfgs: Vec<_> =
            (0..pool).map(|i| with_dma_width(&cfg, widths[i % widths.len()])).collect();
        Scheduler::new_heterogeneous(cfgs, policy)
    } else {
        Scheduler::new(cfg, pool, policy)
    }
    .with_cache(!flag(args, "--no-cache"))
    .with_batching(!flag(args, "--no-batch"))
    .with_verify(!flag(args, "--no-verify"));
    if let Some(bw_arg) = opt(args, "--board-bw") {
        match bw_arg.parse::<u64>() {
            Ok(bw) => sched = sched.with_board(BoardSpec::with_bandwidth(bw)),
            Err(_) => {
                eprintln!("--board-bw expects bytes/cycle, got {bw_arg:?}");
                return 2;
            }
        }
    }
    let handles = sched.submit_all(&stream);
    if let Err(e) = sched.drain() {
        eprintln!("scheduler error: {e}");
        return 1;
    }
    if flag(args, "--events") {
        print!("{}", sched.trace.render());
    }
    let report = sched.report();
    println!("{report}");
    // Every submitted handle must have settled — the async contract.
    let unsettled = handles.iter().filter(|h| !sched.state(**h).settled()).count();
    if unsettled > 0 {
        eprintln!("BUG: {unsettled} handles left unsettled");
        return 1;
    }
    if report.verify_failures > 0 {
        eprintln!("VERIFICATION FAILED for {} job(s)", report.verify_failures);
        return 1;
    }
    0
}

fn cmd_disasm(args: &[String]) -> i32 {
    let w = pick_workload(args);
    let cfg = load_cfg(args);
    let variant = pick_variant(args);
    let opts = LowerOpts::for_config(&cfg);
    let kernel = match variant {
        Variant::Unmodified | Variant::AutoDma => &w.unmodified,
        Variant::Handwritten => &w.handwritten,
        Variant::Promoted => w.promoted.as_ref().unwrap_or(&w.handwritten),
    };
    let autodma =
        (variant == Variant::AutoDma).then(|| AutoDmaOpts::for_config(&cfg));
    match compiler::compile(kernel, &opts, autodma.as_ref()) {
        Ok((lowered, _)) => {
            println!("{}", compiler::disasm(&lowered.program));
            println!("; {} instructions, {} B of L1 statically allocated",
                lowered.program.len(), lowered.l1_used);
            0
        }
        Err(e) => {
            eprintln!("compile error: {e}");
            1
        }
    }
}

fn cmd_autodma(args: &[String]) -> i32 {
    let w = pick_workload(args);
    let cfg = load_cfg(args);
    println!("=== unmodified OpenMP source ===\n{}", ir::pretty(&w.unmodified));
    match herov2::compiler::autodma::transform(&w.unmodified, &AutoDmaOpts::for_config(&cfg)) {
        Ok((tiled, report)) => {
            println!("=== after AutoDMA ===\n{}", ir::pretty(&tiled));
            println!("report: {report:#?}");
            let u = herov2::compiler::metrics::complexity(&w.unmodified);
            let h = herov2::compiler::metrics::complexity(&w.handwritten);
            println!(
                "handwritten equivalent would cost {}x LoC, {}x cyclomatic — AutoDMA: zero code changes",
                h.loc as f64 / u.loc as f64,
                h.cyclomatic as f64 / u.cyclomatic as f64
            );
            0
        }
        Err(e) => {
            eprintln!("AutoDMA declined: {e}");
            1
        }
    }
}
