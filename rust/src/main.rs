//! `hero` — the HEROv2 platform CLI.
//!
//! ```text
//! hero info [--resources]             platform configurations (Table 1)
//! hero run <kernel> [options]         compile + offload a workload through
//!                                     the unified `Session` API
//!     --variant unmodified|handwritten|promoted|autodma   (default handwritten)
//!     --autotune                      search the AutoDMA knob space (tile
//!                                     side, double-buffering, lowering
//!                                     variant) and run the winner too,
//!                                     reporting tuned vs default cycles
//!                                     (implies --variant autodma)
//!     --threads N                     OpenMP threads (default 8)
//!     --size N                        problem size (default: paper size)
//!     --config FILE                   platform config file (see config::parse)
//!     --no-xpulp                      disable Xpulpv2 codegen
//!     --verify-pjrt                   also check against the PJRT artifact
//! hero disasm <kernel> [--variant V] [--size N]   dump device assembly
//! hero autodma <kernel> [--size N]    show the AutoDMA transformation
//! hero kernels                        list workloads (Table 2)
//! hero serve [options]                drain a job stream through a pooled
//!                                     `Session` (multi-accelerator
//!                                     scheduler, one shared carrier-board
//!                                     DRAM) — or a whole board fleet
//!     --jobs N                        synthetic jobs in the stream (default 100)
//!     --trace FILE                    replay a job trace instead of the
//!                                     synthetic stream (lines:
//!                                     `arrival kernel size [variant] [threads] [seed] [priority] [tenant]`;
//!                                     the tenant column needs --fleet)
//!     --pool K                        accelerator instances (default 4)
//!     --fleet N                       serve across N independent carrier
//!                                     boards (each with its own --pool
//!                                     instances, DRAM ledger and binary
//!                                     cache) behind the front-tier fleet
//!                                     router: per-tenant admission QoS and
//!                                     affinity-aware cross-board placement
//!                                     (see rust/src/fleet/README.md)
//!     --route finish|round-robin      fleet routing policy (default finish:
//!                                     best predicted finish across all
//!                                     boards' slots, cache-cold boards pay
//!                                     the compile cost in their score;
//!                                     round-robin is the blind baseline)
//!     --tenants SPEC                  register fleet tenants, comma-
//!                                     separated `name[:jobs[:bytes[:prio]]]`
//!                                     (in-flight / resident-byte quotas,
//!                                     0 = unlimited; prio = default class);
//!                                     trace lines bill jobs to tenants via
//!                                     the trailing tenant column
//!     --policy fifo|sjf|capacity|cap-reject    dispatch policy (default fifo)
//!     --placement earliest|pressure   placement engine (default earliest;
//!                                     pressure scores slots by predicted
//!                                     finish incl. board DRAM stall)
//!     --priority-headroom B           bytes/cycle of board DRAM reachable
//!                                     only by priority-class jobs (default 0)
//!     --autotune                      schedule-time AutoDMA tuning: every
//!                                     autodma job's tiling recipe (tile
//!                                     side, double-buffering, lowering
//!                                     variant) is searched once per
//!                                     (kernel, size, width, config) key,
//!                                     memoized, and the winner's binary is
//!                                     dispatched; with --learn, measured
//!                                     cycles re-rank the candidates
//!     --learn                         online cycle-prediction refinement:
//!                                     blend each settled job's measured
//!                                     device cycles into a deterministic
//!                                     fixed-point EWMA that SJF, pressure
//!                                     placement and inflation consult; the
//!                                     report shows mean-abs-% prediction
//!                                     error before/after learning
//!     --lookahead K                   score the next K policy-ranked jobs
//!                                     jointly against the pool's slots
//!                                     instead of greedily placing the head
//!                                     (default 1 = greedy, bit-identical
//!                                     to the classic dispatch; max 16)
//!     --preempt                       let arrived High jobs displace
//!                                     queued-but-assigned Normal batch
//!                                     followers back into the queue (never
//!                                     mid-kernel — numerics untouched)
//!     --faults PLAN                   arm a deterministic fault plan
//!                                     (comma-separated `seed=N`,
//!                                     `transient=PCT`, `timeout=PCT`,
//!                                     `kill=BOARD@CYCLE`,
//!                                     `recover=BOARD@CYCLE`, or the `demo`
//!                                     preset; board kills need --fleet —
//!                                     see rust/src/fault/README.md)
//!     --retry N                       retry faulted jobs up to N times with
//!                                     exponential backoff in cycles
//!                                     (default 0 = fail on first fault;
//!                                     priority/arrival/dataflow preserved)
//!     --watchdog MULT                 arm the dispatch watchdog: a job
//!                                     whose measured cycles exceed MULT ×
//!                                     its predicted cycles (or its own
//!                                     max_cycles budget) faults with a
//!                                     deadline fault instead of completing
//!     --queue N                       front-tier retry-after queue: defer
//!                                     up to N over-quota fleet submissions
//!                                     and re-admit them as earlier jobs
//!                                     settle, instead of refusing outright
//!                                     (requires --fleet; default 0 = off)
//!     --pipeline N                    additionally run an N-stage chained
//!                                     kernel pipeline through the same
//!                                     session (each stage consumes the
//!                                     previous stage's device-resident
//!                                     output by handle — no host copies),
//!                                     verify it, and check the session
//!                                     heap returns to its watermark after
//!                                     the buffers are freed (default 0 =
//!                                     off; max 32 stages)
//!     --seed S                        stream seed (default 42)
//!     --svm pin|copy|auto             enable shared-virtual-memory serving
//!                                     and run an SVM kernel stream (VA-
//!                                     described operands resolved through
//!                                     the board IOMMU) alongside the named
//!                                     stream, under the given offload
//!                                     strategy (auto picks pin or copy per
//!                                     launch by exact predicted cost)
//!     --host-bw B                     host port bandwidth into the board
//!                                     DRAM in bytes/cycle (default 8;
//!                                     requires --svm)
//!     --board-bw B                    shared board DRAM bandwidth in
//!                                     bytes/cycle (default: config
//!                                     dram.bytes_per_cycle)
//!     --mixed-widths                  heterogeneous pool cycling 64/32/128-bit
//!                                     wide-NoC instances
//!     --no-cache                      disable the lowered-binary cache
//!     --no-batch                      disable same-binary batching
//!     --no-verify                     skip per-job golden-model checks
//!     --events                        dump the scheduler event log
//!     --config FILE                   platform config file
//! ```
//!
//! Every subcommand parses its arguments through the shared declarative
//! parser (`herov2::cli`), so unknown flags and malformed values are
//! errors rather than silently ignored.

use herov2::bench_harness::{figures, verify_arrays, verify_pjrt_arrays, Variant};
use herov2::cli;
use herov2::compiler::{self, ir, AutoDmaOpts, LowerOpts};
use herov2::config::{self, aurora, HeroConfig};
use herov2::runtime::pjrt::PjrtRuntime;
use herov2::workloads;
use herov2::Session;
use std::process::exit;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("info") => cmd_info(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("disasm") => cmd_disasm(&args[1..]),
        Some("autodma") => cmd_autodma(&args[1..]),
        Some("kernels") => {
            print!("{}", figures::table2());
            0
        }
        Some("serve") => cmd_serve(&args[1..]),
        _ => {
            eprintln!("usage: hero <info|run|disasm|autodma|kernels|serve> [options]");
            2
        }
    };
    exit(code);
}

fn parse_args(spec: &cli::Spec, raw: &[String]) -> cli::Args {
    cli::parse(spec, raw).unwrap_or_else(|e| {
        eprintln!("{e}");
        exit(2)
    })
}

/// Parse an option value with a default; malformed input is a hard error.
fn opt_or<T: std::str::FromStr>(args: &cli::Args, name: &str, default: T) -> T {
    match args.parsed::<T>(name) {
        Ok(Some(v)) => v,
        Ok(None) => default,
        Err(e) => {
            eprintln!("{e}");
            exit(2)
        }
    }
}

fn load_cfg(args: &cli::Args) -> HeroConfig {
    let mut cfg = match args.opt("--config") {
        Some(path) => config::parse::load(path).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            exit(2)
        }),
        None => aurora(),
    };
    if args.flag("--no-xpulp") {
        cfg.accel.isa.xpulp = false;
    }
    cfg
}

fn pick_workload(args: &cli::Args) -> workloads::Workload {
    let name = args.positional.first().cloned().unwrap_or_default();
    let size = args.parsed::<usize>("--size").unwrap_or_else(|e| {
        eprintln!("{e}");
        exit(2)
    });
    match size {
        Some(n) => workloads::build(&name, n),
        None => workloads::by_name(&name),
    }
    .unwrap_or_else(|| {
        eprintln!("unknown kernel {name:?}; see `hero kernels`");
        exit(2)
    })
}

fn pick_variant(args: &cli::Args) -> Variant {
    match args.opt("--variant") {
        None | Some("handwritten") => Variant::Handwritten,
        Some("unmodified") => Variant::Unmodified,
        Some("promoted") => Variant::Promoted,
        Some("autodma") => Variant::AutoDma,
        Some(v) => {
            eprintln!("unknown variant {v:?}");
            exit(2)
        }
    }
}

fn cmd_info(raw: &[String]) -> i32 {
    const SPEC: cli::Spec =
        cli::Spec { flags: &["--resources"], opts: &[], max_positional: 0 };
    let args = parse_args(&SPEC, raw);
    print!("{}", figures::table1());
    if args.flag("--resources") {
        use herov2::config::resources::{estimate, utilization, VU37P, ZU9EG};
        for (cfg, carrier) in [
            (aurora(), &ZU9EG),
            (config::blizzard(), &ZU9EG),
            (config::cyclone(), &VU37P),
        ] {
            let u = utilization(&cfg, carrier);
            let e = estimate(&cfg, carrier);
            println!(
                "{:<10} on {:<14}: CLB {:>5.1}%  BRAM {:>5.1}%  DSP {:>4.1}%  ~{:.0} MHz  fits={}",
                cfg.name,
                carrier.name,
                100.0 * u.clb,
                100.0 * u.bram,
                100.0 * u.dsp,
                e.freq_mhz,
                u.fits
            );
        }
    }
    0
}

fn cmd_run(raw: &[String]) -> i32 {
    const SPEC: cli::Spec = cli::Spec {
        flags: &["--autotune", "--no-xpulp", "--verify-pjrt"],
        opts: &["--variant", "--threads", "--size", "--config"],
        max_positional: 1,
    };
    let args = parse_args(&SPEC, raw);
    let cfg = load_cfg(&args);
    let w = pick_workload(&args);
    let autotune = args.flag("--autotune");
    let variant = if autotune {
        match args.opt("--variant") {
            None | Some("autodma") => Variant::AutoDma,
            Some(v) => {
                eprintln!("--autotune tunes the autodma variant; drop `--variant {v}`");
                return 2;
            }
        }
    } else {
        pick_variant(&args)
    };
    let threads: u32 = opt_or(&args, "--threads", 8);
    let seed = 42;
    println!(
        "running {} (N={}) {} with {threads} thread(s) on {}",
        w.name,
        w.size,
        variant.label(),
        cfg.name
    );
    // One unified front door: a single-accelerator session.
    let mut sess = Session::single(cfg.clone());
    let out = match sess.run_workload(&w, variant, threads, seed) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("offload failed: {e}");
            return 1;
        }
    };
    if let Err(e) = verify_arrays(&w, &out.arrays, seed) {
        eprintln!("VERIFICATION FAILED: {e}");
        return 1;
    }
    let res = &out.result;
    println!("device cycles : {:>12}", res.device_cycles);
    println!(
        "end-to-end    : {:>12} ({:.2} ms at {} MHz)",
        res.total_cycles,
        res.total_cycles as f64 / (cfg.accel.freq_mhz as f64 * 1e3),
        cfg.accel.freq_mhz
    );
    println!(
        "dma cycles    : {:>12} ({:.2}%)",
        res.dma_cycles(),
        100.0 * res.dma_cycles() as f64 / res.device_cycles as f64
    );
    println!("verified against the host golden model: OK");
    if let Some(r) = &res.autodma {
        println!("AutoDMA: tiles {:?}, remote {:?}", r.tile_sides, r.remote);
    }
    // The tuned run rides the same session: the winning recipe compiles
    // under its own cache key, and its numerics must match the default
    // recipe's bit for bit.
    if autotune {
        let tuned = match sess.run_workload_tuned(&w, threads, seed) {
            Ok(o) => o,
            Err(e) => {
                eprintln!("tuned offload failed: {e}");
                return 1;
            }
        };
        if let Err(e) = verify_arrays(&w, &tuned.arrays, seed) {
            eprintln!("TUNED VERIFICATION FAILED: {e}");
            return 1;
        }
        let t = &tuned.result;
        if t.digest != res.digest {
            eprintln!("BUG: tuned digest {:#x} != default {:#x}", t.digest, res.digest);
            return 1;
        }
        if let Some(r) = &t.autodma {
            println!(
                "tuned AutoDMA : tiles {:?}, double-buffered {:?}",
                r.tile_sides, r.double_buffered
            );
        } else {
            println!("tuned AutoDMA : direct lowering (no staging) won the search");
        }
        println!(
            "autotune      : default {} cy -> tuned {} cy ({:.2}x), digests identical",
            res.device_cycles,
            t.device_cycles,
            res.device_cycles as f64 / t.device_cycles as f64
        );
    }
    if args.flag("--verify-pjrt") {
        let mut rt = match PjrtRuntime::new(PjrtRuntime::default_dir()) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("PJRT unavailable: {e}");
                return 1;
            }
        };
        match verify_pjrt_arrays(&mut rt, &w, &out.arrays, seed) {
            Ok(true) => println!("verified against the PJRT JAX/Pallas artifact: OK"),
            Ok(false) => println!("PJRT artifact {} not built (run `make artifacts`)", w.pjrt.name),
            Err(e) => {
                eprintln!("PJRT VERIFICATION FAILED: {e}");
                return 1;
            }
        }
    }
    println!("\ndevice counters:\n{}", res.perf.report());
    0
}

fn cmd_serve(raw: &[String]) -> i32 {
    use herov2::config::preset::with_dma_width;
    use herov2::sched::{BoardSpec, Placement, Policy, Scheduler};
    use herov2::workloads::synth;

    const SPEC: cli::Spec = cli::Spec {
        flags: &[
            "--autotune",
            "--events",
            "--learn",
            "--mixed-widths",
            "--no-batch",
            "--no-cache",
            "--no-verify",
            "--no-xpulp",
            "--preempt",
        ],
        opts: &[
            "--board-bw",
            "--config",
            "--faults",
            "--fleet",
            "--host-bw",
            "--jobs",
            "--lookahead",
            "--pipeline",
            "--placement",
            "--policy",
            "--pool",
            "--priority-headroom",
            "--queue",
            "--retry",
            "--route",
            "--seed",
            "--svm",
            "--tenants",
            "--trace",
            "--watchdog",
        ],
        max_positional: 0,
    };
    let args = parse_args(&SPEC, raw);
    let cfg = load_cfg(&args);
    let jobs: usize = opt_or(&args, "--jobs", 100);
    let pool: usize = opt_or(&args, "--pool", 4);
    let seed: u64 = opt_or(&args, "--seed", 42);
    let policy_arg = args.opt("--policy").unwrap_or("fifo");
    let Some(policy) = Policy::parse(policy_arg) else {
        eprintln!("unknown policy {policy_arg:?} (fifo|sjf|capacity|cap-reject)");
        return 2;
    };
    let placement_arg = args.opt("--placement").unwrap_or("earliest");
    let Some(placement) = Placement::parse(placement_arg) else {
        eprintln!("unknown placement {placement_arg:?} (earliest|pressure)");
        return 2;
    };
    let svm_mode = match args.opt("--svm") {
        Some(s) => match herov2::svm::SvmMode::parse(s) {
            Ok(m) => Some(m),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
        None => None,
    };
    let host_bw: u64 = opt_or(&args, "--host-bw", herov2::svm::DEFAULT_HOST_BW);
    if args.opt("--host-bw").is_some() && svm_mode.is_none() {
        eprintln!("--host-bw requires --svm (the host port only exists with SVM serving)");
        return 2;
    }
    let headroom: u64 = opt_or(&args, "--priority-headroom", 0);
    let lookahead: usize = opt_or(&args, "--lookahead", 1);
    if lookahead == 0 || lookahead > 16 {
        eprintln!("--lookahead must be between 1 (greedy dispatch) and 16");
        return 2;
    }
    let pipeline: usize = opt_or(&args, "--pipeline", 0);
    if pipeline > 32 {
        eprintln!("--pipeline supports at most 32 stages");
        return 2;
    }
    if pool == 0 {
        eprintln!("--pool must be at least 1");
        return 2;
    }
    // Resilience: deterministic fault plan, bounded retries, watchdog.
    let faults = match args.opt("--faults") {
        Some(spec) => match herov2::fault::parse(spec) {
            Ok(p) => Some(p),
            Err(e) => {
                eprintln!("--faults error: {e}");
                return 2;
            }
        },
        None => None,
    };
    let retry: u32 = opt_or(&args, "--retry", 0);
    let watchdog = match args.parsed::<u64>("--watchdog") {
        Ok(w) => w,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if watchdog == Some(0) {
        eprintln!("--watchdog must be at least 1 (deadline = MULT x predicted cycles)");
        return 2;
    }
    let queue: usize = opt_or(&args, "--queue", 0);
    // Fleet serving: N independent boards behind the front-tier router.
    let fleet_boards: usize = opt_or(&args, "--fleet", 0);
    if args.opt("--fleet").is_some() && fleet_boards == 0 {
        eprintln!("--fleet must be at least 1 board");
        return 2;
    }
    let route_arg = args.opt("--route").unwrap_or("finish");
    let Some(route) = herov2::fleet::RoutePolicy::parse(route_arg) else {
        eprintln!("unknown route {route_arg:?} (finish|round-robin)");
        return 2;
    };
    let tenants = match args.opt("--tenants") {
        None => Vec::new(),
        Some(spec) => match herov2::fleet::parse_tenants(spec) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("--tenants error: {e}");
                return 2;
            }
        },
    };
    if fleet_boards == 0 && (args.opt("--route").is_some() || args.opt("--tenants").is_some()) {
        eprintln!("--route and --tenants only apply to fleet serving (--fleet N)");
        return 2;
    }
    if fleet_boards == 0 {
        if args.opt("--queue").is_some() {
            eprintln!(
                "--queue only applies to fleet serving (--fleet N): the retry-after \
                 queue lives at the front-tier router"
            );
            return 2;
        }
        if faults.as_ref().is_some_and(|p| !p.boards.is_empty()) {
            eprintln!("--faults board kills (kill=B@C) require --fleet");
            return 2;
        }
    }
    if fleet_boards > 0 {
        for (flag, why) in [
            ("--svm", "shared virtual memory is a per-board IOMMU feature"),
            ("--pipeline", "chained kernel launches run on a single board"),
            ("--mixed-widths", "fleet boards are homogeneous; configure per-board pools instead"),
        ] {
            let given = match flag {
                "--mixed-widths" => args.flag(flag),
                _ => args.opt(flag).is_some(),
            };
            if given {
                eprintln!("{flag} is incompatible with --fleet: {why}");
                return 2;
            }
        }
    }
    let stream: Vec<synth::TraceJob> = match args.opt("--trace") {
        Some(path) => {
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("cannot read trace {path:?}: {e}");
                    return 2;
                }
            };
            match synth::parse_trace(&text) {
                Ok(jobs) => {
                    println!("replaying {} jobs from trace {path}", jobs.len());
                    jobs
                }
                Err(e) => {
                    eprintln!("trace error: {e}");
                    return 2;
                }
            }
        }
        None => synth::mixed_jobs(jobs, seed)
            .into_iter()
            .map(|desc| synth::TraceJob { desc, tenant: None })
            .collect(),
    };
    let board = match args.parsed::<u64>("--board-bw") {
        Ok(Some(bw)) => BoardSpec::with_bandwidth(bw),
        Ok(None) => BoardSpec::from_config(&cfg),
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    }
    .with_priority_headroom(headroom);
    if headroom >= board.dram_bytes_per_cycle {
        eprintln!(
            "--priority-headroom {headroom} must be below the board bandwidth ({} B/cycle); \
             it would throttle all normal traffic to 1 B/cycle",
            board.dram_bytes_per_cycle
        );
        return 2;
    }
    if fleet_boards > 0 {
        println!(
            "serving {} jobs on a {fleet_boards}-board {} fleet \
             (pool {pool} per board, policy {}, placement {}, route {}, seed {seed})",
            stream.len(),
            cfg.name,
            policy.label(),
            placement.label(),
            route.label()
        );
        if faults.is_some() || retry > 0 || watchdog.is_some() || queue > 0 {
            println!(
                "resilience: faults {}, retry {retry}, watchdog {}, queue {queue}",
                if faults.is_some() { "armed" } else { "off" },
                watchdog.map_or("off".to_string(), |m| format!("{m}x")),
            );
        }
        let boards: Vec<Scheduler> = (0..fleet_boards)
            .map(|_| {
                let mut s = Scheduler::new(cfg.clone(), pool, policy)
                    .with_placement(placement)
                    .with_board(board)
                    .with_cache(!args.flag("--no-cache"))
                    .with_batching(!args.flag("--no-batch"))
                    .with_verify(!args.flag("--no-verify"))
                    .with_learning(args.flag("--learn"))
                    .with_lookahead(lookahead)
                    .with_preemption(args.flag("--preempt"))
                    .with_autotune(args.flag("--autotune"))
                    .with_retry(retry);
                if let Some(plan) = faults.clone() {
                    s = s.with_faults(plan);
                }
                if let Some(mult) = watchdog {
                    s = s.with_watchdog(mult);
                }
                s
            })
            .collect();
        let mut router =
            herov2::fleet::Router::new(boards).with_route(route).with_queue(queue);
        if let Some(plan) = &faults {
            router = router.with_faults(plan);
        }
        for spec in tenants {
            router.tenant(spec);
        }
        for tj in &stream {
            let tenant = match &tj.tenant {
                Some(name) => router.tenant_named(name),
                None => herov2::fleet::DEFAULT_TENANT,
            };
            router.submit_for(tenant, tj.desc);
        }
        let mut sess = Session::with_router(router);
        if let Err(e) = sess.drain() {
            eprintln!("fleet error: {e}");
            return 1;
        }
        if args.flag("--events") {
            print!("{}", sess.events().expect("fleet session renders events"));
        }
        let report = sess.fleet_report().expect("fleet session reports");
        println!("{report}");
        let verify_failures: usize = report.boards.iter().map(|b| b.verify_failures).sum();
        if verify_failures > 0 {
            eprintln!("VERIFICATION FAILED for {verify_failures} job(s)");
            return 1;
        }
        return 0;
    }
    // Single-board serving: a tenant-tagged trace has no tenants to bill.
    if let Some(tj) = stream.iter().find(|tj| tj.tenant.is_some()) {
        eprintln!(
            "trace bills jobs to tenant {:?}, but tenancy is a fleet feature — \
             replay it with --fleet N",
            tj.tenant.as_deref().unwrap_or_default()
        );
        return 2;
    }
    let stream: Vec<synth::JobDesc> = stream.into_iter().map(|tj| tj.desc).collect();
    println!(
        "serving {} jobs on {} (pool {}, policy {}, placement {}, seed {seed})",
        stream.len(),
        cfg.name,
        pool,
        policy.label(),
        placement.label()
    );
    let mut sched = if args.flag("--mixed-widths") {
        let widths = [64u32, 32, 128];
        let cfgs: Vec<_> =
            (0..pool).map(|i| with_dma_width(&cfg, widths[i % widths.len()])).collect();
        Scheduler::new_heterogeneous(cfgs, policy)
    } else {
        Scheduler::new(cfg, pool, policy)
    }
    .with_placement(placement)
    .with_board(board)
    .with_cache(!args.flag("--no-cache"))
    .with_batching(!args.flag("--no-batch"))
    .with_verify(!args.flag("--no-verify"))
    .with_learning(args.flag("--learn"))
    .with_lookahead(lookahead)
    .with_preemption(args.flag("--preempt"))
    .with_autotune(args.flag("--autotune"))
    .with_retry(retry);
    if let Some(plan) = faults.clone() {
        sched = sched.with_faults(plan);
    }
    if let Some(mult) = watchdog {
        sched = sched.with_watchdog(mult);
    }
    if args.flag("--learn") || lookahead > 1 || args.flag("--preempt") || args.flag("--autotune") {
        println!(
            "self-tuning: learn {}, lookahead {lookahead}, preempt {}, autotune {}",
            if args.flag("--learn") { "on" } else { "off" },
            if args.flag("--preempt") { "on" } else { "off" },
            if args.flag("--autotune") { "on" } else { "off" },
        );
    }
    if faults.is_some() || retry > 0 || watchdog.is_some() {
        println!(
            "resilience: faults {}, retry {retry}, watchdog {}",
            if faults.is_some() { "armed" } else { "off" },
            watchdog.map_or("off".to_string(), |m| format!("{m}x")),
        );
    }
    // SVM serving rides alongside the named stream: a kernel stream whose
    // operands live in the shared space, VA-described and resolved through
    // the board IOMMU at dispatch, with host traffic contending on the
    // board DRAM through the host port.
    let mut svm_handles = Vec::new();
    if let Some(mode) = svm_mode {
        sched =
            sched.with_svm(herov2::svm::SvmConfig::new(mode).with_host_bw(host_bw));
        let n = (jobs / 4).max(4);
        svm_handles = match herov2::svm::submit_svm_stream(&mut sched, n, seed, None) {
            Ok(h) => h,
            Err(e) => {
                eprintln!("svm stream error: {e}");
                return 1;
            }
        };
        println!(
            "svm: {n} kernel jobs under the {} strategy (host port {host_bw} B/cycle)",
            mode.label()
        );
    }
    // The pooled session is the serve front door.
    let mut sess = Session::with_scheduler(sched);
    let mut handles = match sess.submit_jobs(&stream) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("submit error: {e}");
            return 1;
        }
    };
    handles.extend(svm_handles.drain(..));
    // The chained pipeline rides the same pooled session as the named
    // stream: each stage consumes the previous one's device-resident
    // output by handle, with zero host round-trips between stages.
    let pipe = if pipeline > 0 {
        match submit_pipeline(&mut sess, pipeline) {
            Ok(p) => Some(p),
            Err(e) => {
                eprintln!("pipeline error: {e}");
                return 1;
            }
        }
    } else {
        None
    };
    if let Err(e) = sess.drain() {
        eprintln!("scheduler error: {e}");
        return 1;
    }
    if let Some(p) = pipe {
        if let Err(e) = finish_pipeline(&mut sess, p) {
            eprintln!("pipeline error: {e}");
            return 1;
        }
    }
    if args.flag("--events") {
        print!("{}", sess.events().expect("pooled session renders events"));
    }
    let report = sess.report().expect("pooled session reports");
    println!("{report}");
    // Every submitted handle must have settled — the async contract.
    let unsettled = handles
        .iter()
        .filter(|h| !sess.job_state(**h).is_some_and(|s| s.settled()))
        .count();
    if unsettled > 0 {
        eprintln!("BUG: {unsettled} handles left unsettled");
        return 1;
    }
    if report.verify_failures > 0 {
        eprintln!("VERIFICATION FAILED for {} job(s)", report.verify_failures);
        return 1;
    }
    0
}

/// An in-flight `hero serve --pipeline` run: the chained buffer, its tail
/// launch, the input data and the session watermark to restore after free.
struct PipelineRun {
    buf: herov2::session::Buffer,
    tail: herov2::session::Launch,
    data: Vec<f32>,
    watermark: u64,
    stages: usize,
}

/// Submit an N-stage device-resident pipeline: every stage doubles the
/// buffer in place, chained on the previous stage's pending output (the
/// scheduler's cross-launch dataflow — no host copies between stages).
fn submit_pipeline(sess: &mut Session, stages: usize) -> herov2::Result<PipelineRun> {
    use herov2::compiler::ir::{cf, ci, ld, par_for, st, var, KernelBuilder};
    let n = 256usize;
    let mut b = KernelBuilder::new("serve_pipeline_scale");
    let x = b.host_array("X", vec![ci(n as i32)]);
    let i = b.loop_var("i");
    let kernel = b.body(vec![par_for(
        i,
        ci(0),
        ci(n as i32),
        vec![st(x, vec![var(i)], ld(x, vec![var(i)]).mul(cf(2.0)))],
    )]);
    let data: Vec<f32> = (0..n).map(|i| (i % 17) as f32 + 1.0).collect();
    let watermark = sess.resident_bytes();
    let buf = sess.buffer_from_f32(&data);
    let mut tail = None;
    for _ in 0..stages {
        tail = Some(sess.launch(&kernel).writes(&buf).submit()?);
    }
    Ok(PipelineRun { buf, tail: tail.expect("stages >= 1"), data, watermark, stages })
}

/// Resolve and verify the pipeline (each stage doubles, so the expected
/// result is exact in f32), then free its buffer and check the session
/// heap returns to its pre-pipeline watermark — the bounded-serve-loop
/// guarantee.
fn finish_pipeline(sess: &mut Session, p: PipelineRun) -> herov2::Result<()> {
    let res = sess.wait(&p.tail)?;
    let got = sess.read_f32(&p.buf)?;
    let scale = (1u64 << p.stages) as f32;
    for (i, v) in got.iter().enumerate() {
        anyhow::ensure!(*v == p.data[i] * scale, "pipeline output mismatch at element {i}");
    }
    println!(
        "pipeline: {} chained device-resident stage(s) OK (digest {:#018x}, {} B resident)",
        p.stages,
        res.digest,
        sess.resident_bytes()
    );
    sess.free(&p.buf)?;
    anyhow::ensure!(
        sess.resident_bytes() == p.watermark,
        "session heap did not return to its watermark after free"
    );
    println!(
        "pipeline buffers freed: resident bytes back to the watermark ({} B)",
        p.watermark
    );
    Ok(())
}

fn cmd_disasm(raw: &[String]) -> i32 {
    const SPEC: cli::Spec = cli::Spec {
        flags: &["--no-xpulp"],
        opts: &["--variant", "--size", "--config"],
        max_positional: 1,
    };
    let args = parse_args(&SPEC, raw);
    let w = pick_workload(&args);
    let cfg = load_cfg(&args);
    let variant = pick_variant(&args);
    let opts = LowerOpts::for_config(&cfg);
    let kernel = match variant {
        Variant::Unmodified | Variant::AutoDma => &w.unmodified,
        Variant::Handwritten => &w.handwritten,
        Variant::Promoted => w.promoted.as_ref().unwrap_or(&w.handwritten),
    };
    let autodma =
        (variant == Variant::AutoDma).then(|| AutoDmaOpts::for_config(&cfg));
    match compiler::compile(kernel, &opts, autodma.as_ref()) {
        Ok((lowered, _)) => {
            println!("{}", compiler::disasm(&lowered.program));
            println!("; {} instructions, {} B of L1 statically allocated",
                lowered.program.len(), lowered.l1_used);
            0
        }
        Err(e) => {
            eprintln!("compile error: {e}");
            1
        }
    }
}

fn cmd_autodma(raw: &[String]) -> i32 {
    const SPEC: cli::Spec =
        cli::Spec { flags: &["--no-xpulp"], opts: &["--size", "--config"], max_positional: 1 };
    let args = parse_args(&SPEC, raw);
    let w = pick_workload(&args);
    let cfg = load_cfg(&args);
    println!("=== unmodified OpenMP source ===\n{}", ir::pretty(&w.unmodified));
    match herov2::compiler::autodma::transform(&w.unmodified, &AutoDmaOpts::for_config(&cfg)) {
        Ok((tiled, report)) => {
            println!("=== after AutoDMA ===\n{}", ir::pretty(&tiled));
            println!("report: {report:#?}");
            let u = herov2::compiler::metrics::complexity(&w.unmodified);
            let h = herov2::compiler::metrics::complexity(&w.handwritten);
            println!(
                "handwritten equivalent would cost {}x LoC, {}x cyclomatic — AutoDMA: zero code changes",
                h.loc as f64 / u.loc as f64,
                h.cyclomatic as f64 / u.cyclomatic as f64
            );
            0
        }
        Err(e) => {
            eprintln!("AutoDMA declined: {e}");
            1
        }
    }
}
