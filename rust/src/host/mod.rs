//! Host-side model: user-space process memory, the accelerator driver's
//! buffer management, and the mailbox.
//!
//! §2.3: "The OS device driver and the accompanying user-space accelerator
//! library on the host implement the accelerator-specific functionality for
//! offloading to and communicating with the accelerator ... and making the
//! page table of the user-space process readable for the accelerator."
//!
//! The host is not simulated at instruction level (its cost enters as the
//! configured offload overheads); what matters to the experiments is its
//! *memory state*: user-space buffers live at 64-bit virtual addresses,
//! mapped page-by-page onto physical DRAM, and the accelerator reaches them
//! through the hybrid IOMMU.

use crate::accel::Accel;
use anyhow::{bail, Result};

/// Base of the user-space heap VA window. All buffers share the upper
/// 32 bits (one 4 GiB window), matching the compiler's single
/// address-extension CSR write per kernel.
pub const VA_BASE: u64 = 0x40_0000_0000;

/// A user-space buffer shared with the accelerator.
#[derive(Debug, Clone, Copy)]
pub struct HostBuf {
    /// Virtual address (what the kernel sees via the map clause).
    pub va: u64,
    /// Physical address (contiguous in this model; the page table is still
    /// exercised page-by-page).
    pub pa: u64,
    /// Length in f32 elements.
    pub elems: usize,
}

impl HostBuf {
    /// Upper 32 bits of the VA (the ext-CSR value).
    pub fn hi(&self) -> u32 {
        (self.va >> 32) as u32
    }

    /// Lower 32 bits of the VA.
    pub fn lo(&self) -> u32 {
        self.va as u32
    }
}

/// The host process context: a VA/PA bump allocator over the shared DRAM,
/// maintaining the application page table.
#[derive(Debug)]
pub struct HostContext {
    next_va: u64,
    next_pa: u64,
    dram_bytes: u64,
}

impl Default for HostContext {
    fn default() -> Self {
        Self::new()
    }
}

impl HostContext {
    pub fn new() -> Self {
        HostContext { next_va: VA_BASE, next_pa: 0, dram_bytes: 0 }
    }

    /// Allocate an f32 buffer, map its pages, and return it.
    pub fn alloc(&mut self, accel: &mut Accel, elems: usize) -> Result<HostBuf> {
        if self.dram_bytes == 0 {
            self.dram_bytes = accel.dram.mem.bytes() as u64;
        }
        let page = accel.cfg.iommu.page_bytes as u64;
        let bytes = (elems as u64 * 4).div_ceil(page) * page;
        if self.next_pa + bytes > self.dram_bytes {
            bail!(
                "host allocator out of simulated DRAM ({} + {} > {})",
                self.next_pa,
                bytes,
                self.dram_bytes
            );
        }
        let buf = HostBuf { va: self.next_va, pa: self.next_pa, elems };
        accel.pt.map_range(buf.va, buf.pa, bytes);
        self.next_va += bytes;
        self.next_pa += bytes;
        Ok(buf)
    }

    /// Write data into a buffer (host-side store, physical path).
    pub fn write_f32(&self, accel: &mut Accel, buf: &HostBuf, data: &[f32]) {
        assert!(data.len() <= buf.elems, "write beyond buffer");
        for (i, v) in data.iter().enumerate() {
            accel.dram.mem.store_f32(buf.pa as u32 + (i as u32) * 4, *v);
        }
    }

    /// Read a buffer back.
    pub fn read_f32(&self, accel: &Accel, buf: &HostBuf) -> Vec<f32> {
        (0..buf.elems)
            .map(|i| accel.dram.mem.load_f32(buf.pa as u32 + (i as u32) * 4))
            .collect()
    }
}

/// The hardware mailbox: the host writes a descriptor, the device's offload
/// manager core is woken by interrupt (§2.3). Costs are configured; the
/// functional part is the descriptor handoff done by `runtime::omp`.
#[derive(Debug, Default)]
pub struct Mailbox {
    /// Offloads triggered so far.
    pub offloads: u64,
}

impl Mailbox {
    /// Size of one offload descriptor (kernel id, map-clause pointers,
    /// scalar args) as written through the mailbox. The SVM host port books
    /// this much DRAM traffic per offload so mailbox writes contend like
    /// any other host traffic.
    pub const DESCRIPTOR_BYTES: u64 = 64;

    /// Total cycle cost of one offload round-trip (doorbell + interrupt +
    /// manager dispatch + completion signal).
    pub fn round_trip_cycles(cfg: &crate::config::HeroConfig) -> u64 {
        cfg.timing.offload_host + cfg.timing.offload_dev
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::aurora;

    #[test]
    fn alloc_maps_pages_and_roundtrips() {
        let mut accel = Accel::new(aurora(), 1 << 20);
        let mut host = HostContext::new();
        let buf = host.alloc(&mut accel, 1000).unwrap();
        assert_eq!(buf.hi(), 0x40);
        let data: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5).collect();
        host.write_f32(&mut accel, &buf, &data);
        assert_eq!(host.read_f32(&accel, &buf), data);
        // The page table must translate the whole range.
        for off in [0u64, 2048, 3999] {
            let pa = accel.pt.walk(buf.va + off).unwrap();
            assert_eq!(pa, buf.pa + off);
        }
    }

    #[test]
    fn buffers_do_not_overlap() {
        let mut accel = Accel::new(aurora(), 1 << 20);
        let mut host = HostContext::new();
        let a = host.alloc(&mut accel, 100).unwrap();
        let b = host.alloc(&mut accel, 100).unwrap();
        assert!(a.va + 400 <= b.va);
        assert!(a.pa + 400 <= b.pa);
    }

    #[test]
    fn exhaustion_errors() {
        let mut accel = Accel::new(aurora(), 64 * 1024);
        let mut host = HostContext::new();
        assert!(host.alloc(&mut accel, 100_000).is_err());
    }

    #[test]
    fn alloc_is_page_rounded_and_page_aligned() {
        let mut accel = Accel::new(aurora(), 1 << 20);
        let page = aurora().iommu.page_bytes as u64;
        let mut host = HostContext::new();
        // 1 element still consumes (and advances by) a whole page, and
        // every buffer starts page-aligned — the map_range precondition.
        let a = host.alloc(&mut accel, 1).unwrap();
        let b = host.alloc(&mut accel, 1).unwrap();
        assert_eq!(a.va % page, 0);
        assert_eq!(a.pa % page, 0);
        assert_eq!(b.va - a.va, page);
        assert_eq!(b.pa - a.pa, page);
    }

    #[test]
    fn alloc_advances_the_page_table_epoch() {
        // Each allocation maps pages, so the driver's epoch-conditional
        // flush sees a change exactly once per alloc.
        let mut accel = Accel::new(aurora(), 1 << 20);
        let mut host = HostContext::new();
        let e0 = accel.pt.epoch();
        host.alloc(&mut accel, 64).unwrap();
        assert_eq!(accel.pt.epoch(), e0 + 1);
        host.alloc(&mut accel, 64).unwrap();
        assert_eq!(accel.pt.epoch(), e0 + 2);
    }
}
