//! FPGA resource model (experiment E9).
//!
//! The paper reports the PL utilization of the Aurora build on the ZU9EG:
//! 98.1 % of CLBs (87.7 % PMCA + 10.4 % IOMMU; cores-with-FPU are 38.4 % of
//! the total), 24.2 % of BRAM tiles, 2.9 % of DSP slices, 50 MHz. We have no
//! FPGA, so this module provides an analytical *resource model* calibrated to
//! those numbers, so configuration-space exploration still produces resource
//! estimates (e.g. "does a 16-core cluster fit on a ZU9EG?").

use super::HeroConfig;

/// Resource capacity of a carrier FPGA.
#[derive(Debug, Clone, Copy)]
pub struct Carrier {
    pub name: &'static str,
    pub clbs: u64,
    pub bram_tiles: u64,
    pub dsp_slices: u64,
}

/// Known carriers (Xilinx data sheets).
pub const ZU9EG: Carrier =
    Carrier { name: "Xilinx ZU9EG", clbs: 34_260, bram_tiles: 912, dsp_slices: 2_520 };
pub const VU37P: Carrier =
    Carrier { name: "Xilinx VU37P", clbs: 162_960, bram_tiles: 2_016, dsp_slices: 9_024 };

/// Resource usage estimate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResourceEstimate {
    pub clbs: f64,
    pub bram_tiles: f64,
    pub dsp_slices: f64,
    /// Estimated achievable clock in MHz.
    pub freq_mhz: f64,
}

/// Per-component CLB cost model, calibrated on the paper's Aurora numbers:
/// total = 0.981 * 34_260 ≈ 33_609 CLBs, of which cores+FPU are 38.4 % of the
/// total (= 12_905 for 8 cores → 1_613/core), the IOMMU 10.4 % (= 3_563), and
/// the remaining PMCA share (interconnect, DMA, icache, peripherals) scales
/// with cluster size and NoC width.
const CLB_PER_CORE: f64 = 1_613.0;
const CLB_IOMMU_BASE: f64 = 3_563.0;
const CLB_CLUSTER_BASE: f64 = 9_560.0; // DMA + event unit + mailbox + icache ctrl
const CLB_PER_BANK: f64 = 350.0; // TCDM interconnect grows with bank count
const CLB_NOC_PER_BIT: f64 = 22.0; // wide NoC datapath per bit

/// BRAM: one 36 Kib tile per 4 KiB of SPM (plus icache).
fn bram_tiles(cfg: &HeroConfig) -> f64 {
    let spm_bytes = cfg.accel.n_clusters * (cfg.accel.l1_bytes + cfg.accel.icache_bytes)
        + cfg.accel.l2_bytes;
    spm_bytes as f64 / 4096.0
}

/// Estimate resources for a configuration on a carrier.
pub fn estimate(cfg: &HeroConfig, _carrier: &Carrier) -> ResourceEstimate {
    let n_cores = cfg.n_accel_cores() as f64;
    let n_clusters = cfg.accel.n_clusters as f64;
    let banks = (cfg.tcdm_banks() * cfg.accel.n_clusters) as f64;
    let clbs = n_cores * CLB_PER_CORE
        + n_clusters * CLB_CLUSTER_BASE
        + banks * CLB_PER_BANK
        + cfg.noc.dma_width_bits as f64 * CLB_NOC_PER_BIT * n_clusters
        + CLB_IOMMU_BASE;
    // DSP: 9 slices per FPU-capable core (fp32 FMA), as on CV32E40P builds.
    let dsp = if cfg.accel.isa.fp { n_cores * 9.0 } else { n_cores * 2.0 };
    // Frequency model: the critical path is LSU → TCDM interconnect →
    // arbiter → LSU (§3); it lengthens with log2(banks) levels of arbitration.
    let base = 62.0; // MHz for a minimal 4-core cluster on UltraScale+
    let freq = base / (1.0 + 0.1 * (banks / n_clusters).log2());
    ResourceEstimate { clbs, bram_tiles: bram_tiles(cfg), dsp_slices: dsp, freq_mhz: freq }
}

/// Utilization report (fractions of the carrier, 0..1+).
#[derive(Debug, Clone, Copy)]
pub struct Utilization {
    pub clb: f64,
    pub bram: f64,
    pub dsp: f64,
    pub fits: bool,
}

/// Compute utilization of `cfg` on `carrier`.
pub fn utilization(cfg: &HeroConfig, carrier: &Carrier) -> Utilization {
    let est = estimate(cfg, carrier);
    let clb = est.clbs / carrier.clbs as f64;
    let bram = est.bram_tiles / carrier.bram_tiles as f64;
    let dsp = est.dsp_slices / carrier.dsp_slices as f64;
    Utilization { clb, bram, dsp, fits: clb <= 1.0 && bram <= 1.0 && dsp <= 1.0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{aurora, cyclone};

    #[test]
    fn aurora_matches_paper_utilization() {
        // Paper: 98.1 % CLB, 24.2 % BRAM, 2.9 % DSP on the ZU9EG at 50 MHz.
        let u = utilization(&aurora(), &ZU9EG);
        assert!((u.clb - 0.981).abs() < 0.05, "clb = {}", u.clb);
        assert!((u.bram - 0.242).abs() < 0.08, "bram = {}", u.bram);
        assert!((u.dsp - 0.029).abs() < 0.01, "dsp = {}", u.dsp);
        let est = estimate(&aurora(), &ZU9EG);
        assert!((est.freq_mhz - 50.0).abs() < 8.0, "freq = {}", est.freq_mhz);
    }

    #[test]
    fn sixteen_core_cluster_overflows_zu9eg() {
        let mut cfg = aurora();
        cfg.accel.cores_per_cluster = 16;
        cfg.accel.l1_bytes = 256 * 1024;
        let u = utilization(&cfg, &ZU9EG);
        assert!(!u.fits, "16-core cluster should not fit: {u:?}");
    }

    #[test]
    fn cyclone_fits_vu37p() {
        let u = utilization(&cyclone(), &VU37P);
        assert!(u.fits, "{u:?}");
    }
}
