//! Table 1 platform presets: Aurora, Blizzard, Cyclone.

use super::*;

/// The *Aurora* configuration — the mature platform evaluated in §3:
/// quad-core ARM Cortex-A53 host at 1.2 GHz + octa-core CV32E40P
/// (RV32IMAFCXpulpv2) cluster with 128 KiB L1 TCDM at 50 MHz on a Xilinx
/// ZU9EG, sharing 4 GiB DDR4 (19.2 GB/s) through a lightweight hybrid IOMMU.
pub fn aurora() -> HeroConfig {
    HeroConfig {
        name: "aurora".into(),
        carrier: "Xilinx ZU9EG".into(),
        status: "mature".into(),
        host: HostConfig {
            isa: "ARMv8.0-A".into(),
            core_arch: "Cortex-A53".into(),
            n_cores: 4,
            freq_mhz: 1200,
            l1_bytes: 32 * 1024,
            l2_bytes: 1024 * 1024,
        },
        accel: AccelConfig {
            core_arch: "CV32E40P".into(),
            isa: IsaExt::RV32IMAFC_XPULPV2,
            n_clusters: 1,
            cores_per_cluster: 8,
            l1_bytes: 128 * 1024,
            banking_factor: 2,
            l2_bytes: 1024 * 1024,
            icache_bytes: 4 * 1024,
            icache_line_insts: 8,
            l0_insts: 8,
            freq_mhz: 50,
        },
        noc: NocConfig { dma_width_bits: 64, narrow_width_bits: 32, max_outstanding: 16 },
        dma: DmaConfig { setup_cycles: 30, max_burst_beats: 256, max_outstanding: 16, burst_overhead: 20, hw_2d: true },
        iommu: IommuConfig {
            // [25] adds TLB prefetching and an MMU-aware DMA engine; we
            // model the combination as a large effective TLB with a
            // software-walk cost of ~150 cycles (the VMM library's walk at
            // the 50 MHz accelerator clock).
            tlb_entries: 1024,
            walk_cycles: 150,
            miss_mode: MissMode::SelfService,
            page_bytes: 4096,
            flush_on_offload: false,
        },
        dram: DramConfig {
            capacity: 4 << 30,
            // ~160 ns DDR4 access at the 50 MHz accelerator clock.
            first_word_cycles: 8,
            // 19.2 GB/s at 50 MHz = 384 B/accel-cycle; NoC (8 B/cycle) is the
            // actual bottleneck, matching the paper's system balance.
            bytes_per_cycle: 384,
        },
        timing: TimingConfig {
            branch_taken: 1,
            l2_access: 10,
            ext_addr_overhead: 3,
            remote_word: 6,
            remote_service: 1,
            icache_refill: 10,
            offload_host: 1500,
            offload_dev: 300,
            barrier: 20,
        },
    }
}

/// The *Blizzard* configuration: same A53 host and ZU9EG carrier, but an
/// octa-core machine-learning-training accelerator based on Snitch cores
/// (RV32IMAFDXssrXfrepXsdma) with 8 GiB HBM2E at up to 460 GB/s.
pub fn blizzard() -> HeroConfig {
    let mut cfg = aurora();
    cfg.name = "blizzard".into();
    cfg.status = "in development".into();
    cfg.accel.core_arch = "Snitch".into();
    // Snitch has no Xpulpv2; its FP subsystem is modelled as the F extension.
    cfg.accel.isa = IsaExt::RV32IMAFC;
    cfg.dram = DramConfig {
        capacity: 8 << 30,
        first_word_cycles: 10,
        bytes_per_cycle: 9200, // 460 GB/s at 50 MHz
    };
    cfg
}

/// The *Cyclone* configuration: single-core RV64GC CVA6 soft host and a
/// 32-core (4 clusters × 8) MLT accelerator on a Xilinx VU37P at 25 MHz.
pub fn cyclone() -> HeroConfig {
    let mut cfg = blizzard();
    cfg.name = "cyclone".into();
    cfg.carrier = "Xilinx VU37P".into();
    cfg.host = HostConfig {
        isa: "RV64GC".into(),
        core_arch: "CVA6".into(),
        n_cores: 1,
        freq_mhz: 25,
        l1_bytes: 32 * 1024,
        l2_bytes: 512 * 1024,
    };
    cfg.accel.n_clusters = 4;
    cfg.accel.freq_mhz = 25;
    cfg
}

/// Derive a variant of `base` with a different wide-NoC data width (the
/// §3.3 sweep axis). When the width actually changes, the name gains a
/// `-w<bits>` suffix so lowered-binary caches and reports keep the variants
/// distinct — the building block for heterogeneous instance pools.
pub fn with_dma_width(base: &HeroConfig, bits: u32) -> HeroConfig {
    let mut cfg = base.clone();
    cfg.noc.dma_width_bits = bits;
    if bits != base.noc.dma_width_bits {
        cfg.name = format!("{}-w{bits}", base.name);
    }
    cfg
}

/// Look a preset up by name (case-insensitive).
pub fn by_name(name: &str) -> Option<HeroConfig> {
    match name.to_ascii_lowercase().as_str() {
        "aurora" => Some(aurora()),
        "blizzard" => Some(blizzard()),
        "cyclone" => Some(cyclone()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_finds_all() {
        for n in ["aurora", "Blizzard", "CYCLONE"] {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("tsunami").is_none());
    }

    #[test]
    fn cyclone_is_multicluster() {
        let c = cyclone();
        assert_eq!(c.n_accel_cores(), 32);
        assert_eq!(c.host.core_arch, "CVA6");
    }

    #[test]
    fn with_dma_width_renames_only_on_change() {
        let base = aurora();
        let w128 = with_dma_width(&base, 128);
        assert_eq!(w128.noc.dma_width_bits, 128);
        assert_eq!(w128.name, "aurora-w128");
        assert!(w128.validate().is_ok());
        let same = with_dma_width(&base, base.noc.dma_width_bits);
        assert_eq!(same.name, "aurora");
    }
}
