//! Platform configuration system.
//!
//! HEROv2 is a *configurable* platform: the paper's Table 1 lists three
//! concrete configurations (Aurora, Blizzard, Cyclone) that differ in host
//! ISA, accelerator core architecture and count, memory capacities and
//! carrier silicon. This module models that configuration space.
//!
//! A [`HeroConfig`] fully determines a simulated platform instance:
//! micro-architectural timing parameters, memory geometry, on-chip network
//! widths and the IOMMU/DMA capabilities. Presets for the paper's three
//! configurations are in [`preset`], and configurations can be loaded from
//! simple `key = value` text files (see [`parse`]) so experiments are
//! scriptable without recompiling.

pub mod parse;
pub mod preset;
pub mod resources;

pub use preset::{aurora, blizzard, cyclone};

/// Host processor configuration (paper §2.1: ARMv8 Cortex-A53 hard macro or
/// RV64GC CVA6 soft macro).
#[derive(Debug, Clone, PartialEq)]
pub struct HostConfig {
    /// Host ISA name, e.g. `"ARMv8.0-A"` or `"RV64GC"`.
    pub isa: String,
    /// Core architecture, e.g. `"Cortex-A53"` or `"CVA6"`.
    pub core_arch: String,
    /// Number of host cores.
    pub n_cores: usize,
    /// Host clock frequency in MHz (1200 for the A53 hard macro).
    pub freq_mhz: u32,
    /// Per-core L1 instruction/data cache size in bytes.
    pub l1_bytes: usize,
    /// Shared L2 cache size in bytes.
    pub l2_bytes: usize,
}

/// Accelerator ISA extension set (paper §2.1 and Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IsaExt {
    /// Single-precision floating point (`F`).
    pub fp: bool,
    /// Xpulpv2: hardware loops, post-increment load/store, MAC.
    pub xpulp: bool,
    /// Atomics (`A`) — always present on HEROv2 cores.
    pub atomics: bool,
}

impl IsaExt {
    /// The baseline ISA evaluated against in §3.4.
    pub const RV32IMAFC: IsaExt = IsaExt { fp: true, xpulp: false, atomics: true };
    /// The full Aurora ISA.
    pub const RV32IMAFC_XPULPV2: IsaExt = IsaExt { fp: true, xpulp: true, atomics: true };

    /// Render as a RISC-V ISA string.
    pub fn name(&self) -> String {
        let mut s = String::from("RV32IM");
        if self.atomics {
            s.push('A');
        }
        if self.fp {
            s.push('F');
        }
        s.push('C');
        if self.xpulp {
            s.push_str("Xpulpv2");
        }
        s
    }
}

/// Accelerator (PMCA) configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct AccelConfig {
    /// Core architecture, e.g. `"CV32E40P"` or `"Snitch"`.
    pub core_arch: String,
    /// ISA extension set.
    pub isa: IsaExt,
    /// Number of clusters.
    pub n_clusters: usize,
    /// Cores per cluster (4..=16 per §2.1; 8 on Aurora).
    pub cores_per_cluster: usize,
    /// L1 TCDM SPM bytes per cluster (128 KiB on Aurora).
    pub l1_bytes: usize,
    /// TCDM banking factor (banks = factor * cores; default 2 per §2.1).
    pub banking_factor: usize,
    /// Shared L2 SPM bytes.
    pub l2_bytes: usize,
    /// Shared L1 instruction cache bytes per cluster.
    pub icache_bytes: usize,
    /// Instructions per icache line.
    pub icache_line_insts: usize,
    /// Per-core L0 loop buffer capacity in (compressed) instructions (§2.1: 8).
    pub l0_insts: usize,
    /// Accelerator clock frequency in MHz (50 on the ZU9EG).
    pub freq_mhz: u32,
}

/// On-chip network configuration (paper §2.1, §3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NocConfig {
    /// Data width of the wide (DMA) network in bits. §3.3 sweeps 32/64/128.
    pub dma_width_bits: u32,
    /// Data width of the narrow (core → remote) network in bits.
    pub narrow_width_bits: u32,
    /// Maximum outstanding burst transactions ("tens" per §2.1).
    pub max_outstanding: u32,
}

/// DMA engine configuration (paper §2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DmaConfig {
    /// Cycles to program one transfer descriptor from a core.
    pub setup_cycles: u64,
    /// Maximum beats per burst ("tens of data beats").
    pub max_burst_beats: u32,
    /// Maximum outstanding bursts.
    pub max_outstanding: u32,
    /// Per-burst issue overhead on the wide path (AR handshake + DRAM bank
    /// access), visible per row of scattered 2D transfers.
    pub burst_overhead: u64,
    /// Whether the engine executes 2D descriptors in hardware (§2.4: if not,
    /// multi-dimensional transfers are implemented in software).
    pub hw_2d: bool,
}

/// Hybrid IOMMU configuration (paper §2.1, §2.3, [21], [25]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IommuConfig {
    /// TLB capacity in entries.
    pub tlb_entries: usize,
    /// Cycles for an on-accelerator page-table walk on TLB miss.
    pub walk_cycles: u64,
    /// Who handles misses: the faulting core or a dedicated handler core.
    pub miss_mode: MissMode,
    /// Page size in bytes (4 KiB like the host MMU).
    pub page_bytes: usize,
    /// Flush the TLB on *every* offload (the pre-epoch driver behavior).
    /// Off by default: the driver now flushes only when the page table
    /// changed since the TLB was last filled, which is what makes warm-TLB
    /// SVM studies possible. Turn on to pin the old behavior.
    pub flush_on_offload: bool,
}

/// TLB miss handling policy (§2.3: configurable per offload).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MissMode {
    /// The core that missed walks the page table itself.
    SelfService,
    /// A dedicated core handles misses (preferable for pointer chasing).
    DedicatedCore,
}

/// Main memory configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DramConfig {
    /// Capacity in bytes.
    pub capacity: u64,
    /// First-word latency seen from the accelerator, in accelerator cycles.
    pub first_word_cycles: u64,
    /// Peak bandwidth in bytes per accelerator cycle on the wide NoC path.
    /// (19.2 GB/s DDR4 at 50 MHz accel clock = 384 B/cycle is far above the
    /// 8 B/cycle NoC limit, so the NoC is the bottleneck — as in the paper.)
    pub bytes_per_cycle: u64,
}

/// Fixed micro-architectural costs (accelerator cycles).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimingConfig {
    /// Taken-branch penalty (pipeline refill).
    pub branch_taken: u64,
    /// L2 SPM access latency from a core.
    pub l2_access: u64,
    /// Extra cycles per remote (64-bit host address space) core access when
    /// the TLB hits — the address-extension CSR path (§2.3: three cycles).
    pub ext_addr_overhead: u64,
    /// Total latency of a remote word access from a core (NoC + DRAM),
    /// excluding `ext_addr_overhead` and TLB effects. At the 50 MHz Aurora
    /// accelerator clock, DRAM + NoC round trips are tens of cycles.
    pub remote_word: u64,
    /// Narrow-NoC port occupancy per remote access: the issue-rate limit
    /// shared by all cores of a cluster.
    pub remote_service: u64,
    /// Icache refill latency (per line, excluding serialization over the
    /// fetch port — that part is width-dependent, see `NocConfig`).
    pub icache_refill: u64,
    /// Host-side cost of triggering an offload (syscall + mailbox doorbell),
    /// in accelerator cycles.
    pub offload_host: u64,
    /// Device-side cost (mailbox interrupt → offload manager dispatch).
    pub offload_dev: u64,
    /// Cluster barrier cost (event-unit synchronization).
    pub barrier: u64,
}

/// A complete platform configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct HeroConfig {
    /// Configuration name (e.g. "aurora").
    pub name: String,
    /// Carrier silicon (e.g. "Xilinx ZU9EG").
    pub carrier: String,
    /// Maturity status as in Table 1.
    pub status: String,
    pub host: HostConfig,
    pub accel: AccelConfig,
    pub noc: NocConfig,
    pub dma: DmaConfig,
    pub iommu: IommuConfig,
    pub dram: DramConfig,
    pub timing: TimingConfig,
}

impl HeroConfig {
    /// Total number of accelerator cores.
    pub fn n_accel_cores(&self) -> usize {
        self.accel.n_clusters * self.accel.cores_per_cluster
    }

    /// Number of TCDM banks per cluster.
    pub fn tcdm_banks(&self) -> usize {
        self.accel.banking_factor * self.accel.cores_per_cluster
    }

    /// L1 capacity available to user data, in 4-byte words. The paper
    /// reserves runtime state: "L = 28 Ki single-precision words can be
    /// stored in L1" out of the 32 Ki-word (128 KiB) TCDM.
    pub fn l1_user_words(&self) -> usize {
        let total_words = self.accel.l1_bytes / 4;
        // Runtime + stacks occupy 1/8 of the TCDM, matching 28Ki/32Ki.
        total_words - total_words / 8
    }

    /// DMA beat size in bytes on the wide NoC.
    pub fn dma_beat_bytes(&self) -> u64 {
        (self.noc.dma_width_bits / 8) as u64
    }

    /// Instruction-fetch bandwidth into the shared icache in bytes/cycle:
    /// bounded by both the NoC width and the cache's 64-bit fill port
    /// (§3.3: "the instruction cache can only fetch at most 64 bit per
    /// cycle").
    pub fn ifetch_bytes_per_cycle(&self) -> u64 {
        ((self.noc.dma_width_bits.min(64)) / 8) as u64
    }

    /// Validate internal consistency. Returns a human-readable error for the
    /// first violated invariant.
    pub fn validate(&self) -> Result<(), String> {
        if self.accel.cores_per_cluster < 1 || self.accel.cores_per_cluster > 16 {
            return Err(format!(
                "cores_per_cluster must be in 1..=16, got {}",
                self.accel.cores_per_cluster
            ));
        }
        if self.accel.n_clusters == 0 {
            return Err("n_clusters must be >= 1".into());
        }
        if !self.noc.dma_width_bits.is_power_of_two() || self.noc.dma_width_bits < 32 {
            return Err(format!(
                "dma_width_bits must be a power of two >= 32, got {}",
                self.noc.dma_width_bits
            ));
        }
        if self.accel.banking_factor == 0 {
            return Err("banking_factor must be >= 1".into());
        }
        if self.accel.l1_bytes % (self.tcdm_banks() * 4) != 0 {
            return Err("l1_bytes must divide evenly across banks".into());
        }
        if !self.iommu.page_bytes.is_power_of_two() {
            return Err("page_bytes must be a power of two".into());
        }
        if self.iommu.tlb_entries == 0 {
            return Err("tlb_entries must be >= 1".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for cfg in [aurora(), blizzard(), cyclone()] {
            cfg.validate().unwrap_or_else(|e| panic!("{}: {}", cfg.name, e));
        }
    }

    #[test]
    fn aurora_matches_table1() {
        let a = aurora();
        assert_eq!(a.host.core_arch, "Cortex-A53");
        assert_eq!(a.host.n_cores, 4);
        assert_eq!(a.accel.cores_per_cluster, 8);
        assert_eq!(a.accel.n_clusters, 1);
        assert_eq!(a.accel.l1_bytes, 128 * 1024);
        assert!(a.accel.isa.xpulp);
        assert_eq!(a.accel.freq_mhz, 50);
    }

    #[test]
    fn l1_user_words_matches_paper() {
        // §3.1: "L = 28 Ki single-precision words can be stored in L1".
        assert_eq!(aurora().l1_user_words(), 28 * 1024);
    }

    #[test]
    fn isa_names() {
        assert_eq!(IsaExt::RV32IMAFC.name(), "RV32IMAFC");
        assert_eq!(IsaExt::RV32IMAFC_XPULPV2.name(), "RV32IMAFCXpulpv2");
    }

    #[test]
    fn ifetch_bandwidth_capped_at_64bit() {
        let mut cfg = aurora();
        cfg.noc.dma_width_bits = 128;
        assert_eq!(cfg.ifetch_bytes_per_cycle(), 8); // capped
        cfg.noc.dma_width_bits = 32;
        assert_eq!(cfg.ifetch_bytes_per_cycle(), 4);
    }

    #[test]
    fn validate_rejects_bad_width() {
        let mut cfg = aurora();
        cfg.noc.dma_width_bits = 48;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validate_rejects_zero_clusters() {
        let mut cfg = aurora();
        cfg.accel.n_clusters = 0;
        assert!(cfg.validate().is_err());
    }
}
