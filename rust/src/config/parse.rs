//! Plain-text configuration files.
//!
//! Experiments are scriptable without recompiling: a config file starts from
//! a named preset and overrides individual fields with `key = value` lines.
//!
//! ```text
//! preset = aurora
//! noc.dma_width_bits = 128
//! accel.cores_per_cluster = 16
//! iommu.miss_mode = dedicated
//! ```
//!
//! Comments start with `#`. Sizes accept `K`/`M`/`G` suffixes (binary).

use super::{preset, HeroConfig, MissMode};

/// Parse a size like `128K` or `4G` into bytes.
fn parse_size(v: &str) -> Result<u64, String> {
    let v = v.trim();
    let (num, mult) = match v.chars().last() {
        Some('K') | Some('k') => (&v[..v.len() - 1], 1u64 << 10),
        Some('M') | Some('m') => (&v[..v.len() - 1], 1u64 << 20),
        Some('G') | Some('g') => (&v[..v.len() - 1], 1u64 << 30),
        _ => (v, 1),
    };
    num.trim().parse::<u64>().map(|n| n * mult).map_err(|e| format!("bad size {v:?}: {e}"))
}

/// Apply one `key = value` override to a config.
pub fn apply_override(cfg: &mut HeroConfig, key: &str, value: &str) -> Result<(), String> {
    let v = value.trim();
    let uint = || v.parse::<u64>().map_err(|e| format!("bad integer {v:?}: {e}"));
    match key.trim() {
        "name" => cfg.name = v.into(),
        "carrier" => cfg.carrier = v.into(),
        "host.n_cores" => cfg.host.n_cores = uint()? as usize,
        "host.freq_mhz" => cfg.host.freq_mhz = uint()? as u32,
        "accel.n_clusters" => cfg.accel.n_clusters = uint()? as usize,
        "accel.cores_per_cluster" => cfg.accel.cores_per_cluster = uint()? as usize,
        "accel.l1_bytes" => cfg.accel.l1_bytes = parse_size(v)? as usize,
        "accel.l2_bytes" => cfg.accel.l2_bytes = parse_size(v)? as usize,
        "accel.banking_factor" => cfg.accel.banking_factor = uint()? as usize,
        "accel.icache_bytes" => cfg.accel.icache_bytes = parse_size(v)? as usize,
        "accel.l0_insts" => cfg.accel.l0_insts = uint()? as usize,
        "accel.freq_mhz" => cfg.accel.freq_mhz = uint()? as u32,
        "accel.xpulp" => cfg.accel.isa.xpulp = parse_bool(v)?,
        "noc.dma_width_bits" => cfg.noc.dma_width_bits = uint()? as u32,
        "noc.narrow_width_bits" => cfg.noc.narrow_width_bits = uint()? as u32,
        "noc.max_outstanding" => cfg.noc.max_outstanding = uint()? as u32,
        "dma.setup_cycles" => cfg.dma.setup_cycles = uint()?,
        "dma.max_burst_beats" => cfg.dma.max_burst_beats = uint()? as u32,
        "dma.max_outstanding" => cfg.dma.max_outstanding = uint()? as u32,
        "dma.burst_overhead" => cfg.dma.burst_overhead = uint()?,
        "dma.hw_2d" => cfg.dma.hw_2d = parse_bool(v)?,
        "iommu.tlb_entries" => cfg.iommu.tlb_entries = uint()? as usize,
        "iommu.walk_cycles" => cfg.iommu.walk_cycles = uint()?,
        "iommu.page_bytes" => cfg.iommu.page_bytes = parse_size(v)? as usize,
        "iommu.flush_on_offload" => cfg.iommu.flush_on_offload = parse_bool(v)?,
        "iommu.miss_mode" => {
            cfg.iommu.miss_mode = match v {
                "self" => MissMode::SelfService,
                "dedicated" => MissMode::DedicatedCore,
                _ => return Err(format!("bad miss_mode {v:?} (self|dedicated)")),
            }
        }
        "dram.capacity" => cfg.dram.capacity = parse_size(v)?,
        "dram.first_word_cycles" => cfg.dram.first_word_cycles = uint()?,
        "dram.bytes_per_cycle" => cfg.dram.bytes_per_cycle = uint()?,
        "timing.branch_taken" => cfg.timing.branch_taken = uint()?,
        "timing.l2_access" => cfg.timing.l2_access = uint()?,
        "timing.ext_addr_overhead" => cfg.timing.ext_addr_overhead = uint()?,
        "timing.remote_word" => cfg.timing.remote_word = uint()?,
        "timing.remote_service" => cfg.timing.remote_service = uint()?,
        "timing.icache_refill" => cfg.timing.icache_refill = uint()?,
        "timing.offload_host" => cfg.timing.offload_host = uint()?,
        "timing.offload_dev" => cfg.timing.offload_dev = uint()?,
        "timing.barrier" => cfg.timing.barrier = uint()?,
        other => return Err(format!("unknown config key {other:?}")),
    }
    Ok(())
}

fn parse_bool(v: &str) -> Result<bool, String> {
    match v {
        "true" | "1" | "yes" | "on" => Ok(true),
        "false" | "0" | "no" | "off" => Ok(false),
        _ => Err(format!("bad bool {v:?}")),
    }
}

/// Parse a full config file (text form). A `preset = <name>` line selects the
/// base; all other lines are overrides applied in order.
pub fn parse_str(text: &str) -> Result<HeroConfig, String> {
    let mut cfg: Option<HeroConfig> = None;
    let mut pending: Vec<(String, String)> = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let (key, value) = (key.trim(), value.trim());
        if key == "preset" {
            cfg = Some(
                preset::by_name(value).ok_or_else(|| format!("unknown preset {value:?}"))?,
            );
        } else if let Some(cfg) = cfg.as_mut() {
            apply_override(cfg, key, value).map_err(|e| format!("line {}: {e}", lineno + 1))?;
        } else {
            pending.push((key.to_string(), value.to_string()));
        }
    }
    let mut cfg = cfg.unwrap_or_else(preset::aurora);
    for (k, v) in pending {
        apply_override(&mut cfg, &k, &v)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Load a config from a file path.
pub fn load(path: &str) -> Result<HeroConfig, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    parse_str(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_preset_with_overrides() {
        let cfg = parse_str(
            "preset = aurora\n\
             noc.dma_width_bits = 128\n\
             accel.l1_bytes = 256K # bigger TCDM\n",
        )
        .unwrap();
        assert_eq!(cfg.noc.dma_width_bits, 128);
        assert_eq!(cfg.accel.l1_bytes, 256 * 1024);
    }

    #[test]
    fn default_preset_is_aurora() {
        let cfg = parse_str("accel.cores_per_cluster = 4\n").unwrap();
        assert_eq!(cfg.name, "aurora");
        assert_eq!(cfg.accel.cores_per_cluster, 4);
    }

    #[test]
    fn rejects_unknown_key() {
        assert!(parse_str("preset = aurora\nbogus.key = 3\n").is_err());
    }

    #[test]
    fn rejects_invalid_final_config() {
        assert!(parse_str("preset = aurora\nnoc.dma_width_bits = 48\n").is_err());
    }

    #[test]
    fn size_suffixes() {
        assert_eq!(parse_size("128K").unwrap(), 128 << 10);
        assert_eq!(parse_size("4G").unwrap(), 4 << 30);
        assert_eq!(parse_size("77").unwrap(), 77);
        assert!(parse_size("x4").is_err());
    }

    #[test]
    fn miss_mode_parse() {
        let cfg = parse_str("preset = aurora\niommu.miss_mode = dedicated\n").unwrap();
        assert_eq!(cfg.iommu.miss_mode, crate::config::MissMode::DedicatedCore);
    }

    #[test]
    fn flush_on_offload_parse() {
        assert!(!parse_str("preset = aurora\n").unwrap().iommu.flush_on_offload);
        let cfg = parse_str("preset = aurora\niommu.flush_on_offload = true\n").unwrap();
        assert!(cfg.iommu.flush_on_offload);
    }
}
