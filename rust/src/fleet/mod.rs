//! Multi-board fleet serving: a front-tier router over N independent
//! carrier boards.
//!
//! One [`crate::sched::Scheduler`] models one carrier board — its own
//! instance pool, shared-DRAM [`crate::mem::BandwidthLedger`], binary
//! cache and (optionally) learning/SVM state. This module scales `hero
//! serve` past a single board: a [`Router`] owns N schedulers and fronts
//! them with one submission API, which is the platform's
//! millions-of-users story (the original HERO platform already networked
//! multiple FPGA boards behind one host; we compose the simulated boards
//! behind one front tier).
//!
//! ## Routing
//!
//! Every submission is scored against every board with exactly the
//! placement engine a single board uses ([`place::scores_from`] — the
//! same `(finish, stall, free_at, index)` ordering as
//! [`place::choose`]), plus two fleet-level terms:
//!
//! * **Projected occupancy.** All submissions typically precede the
//!   drain, when every pool port still reads free-at-0. The router keeps
//!   a per-slot *projected* free cycle — the predicted finish of every
//!   job it has already routed there — and floors each slot's score with
//!   it, so a burst spreads across boards instead of piling onto board 0.
//! * **Binary-cache affinity.** A board that has not compiled the job's
//!   kernel pays its predicted compile cost
//!   ([`cache::compile_cost_cycles`]) in the score; warm boards
//!   (read-only probe via [`cache::BinaryCache::contains`], unioned with
//!   the router's own projection of keys it already routed) do not. Hot
//!   kernels therefore stick to boards that already lowered them, and
//!   the router reports the hit rate ([`FleetReport::affinity_hits`]).
//!
//! [`RoutePolicy::RoundRobin`] bypasses all scoring (strict alternation)
//! — the baseline the affinity bench beats.
//!
//! ## Tenancy and quotas
//!
//! Jobs are tagged with a [`TenantId`]. Each tenant carries fair-share
//! admission quotas ([`TenantSpec`]): a cap on *in-flight* jobs
//! (admitted and not yet settled at submission time — under the
//! submit-then-drain usage this caps a tenant's burst size) and a cap on
//! *resident bytes* (the summed DRAM footprint of its in-flight jobs),
//! plus an optional default [`Priority`] applied to submissions that did
//! not ask for a class themselves. A submission over quota is refused at
//! the front tier — it never reaches any board, so a noisy tenant cannot
//! degrade other tenants beyond its share ([`FleetReport`] carries
//! per-tenant per-class p50/p95 turnaround to verify exactly that).
//!
//! ## Resilience
//!
//! A [`crate::fault::FaultPlan`] may schedule *board failures*
//! ([`Router::with_faults`]): at its kill cycle a board stops accepting
//! dispatches — work already dispatched completes (the front-end dies,
//! the compute fabric finishes its assigned windows), queued named jobs
//! are **evacuated** and re-routed to surviving boards through exactly
//! the admission-time scoring (health-aware: unhealthy boards are
//! skipped by every policy), their fleet handles following them to the
//! new board ([`JobState::Migrated`] on the source,
//! [`SchedEvent::Migrated`] in the timeline). Queued *kernel* jobs carry
//! board-local dataflow and payloads, so they fail in place. A fault
//! plan may also schedule recovery (`recover=B@C`): the board rejoins
//! the healthy set at that cycle and later routing sees it again. The
//! per-board health timelines, migration counts and board-level
//! fault/retry totals surface in [`FleetReport`].
//!
//! With a retry-after queue armed ([`Router::with_queue`]), an
//! over-quota submission is *deferred* at the front tier instead of
//! refused — it waits in a bounded queue (overflow still refuses) and is
//! re-quoted against its tenant's live quota once earlier jobs settle,
//! then routed with the same scoring as a fresh submission
//! ([`FleetReport::queued_then_admitted`]).
//!
//! ## Degenerate identity
//!
//! A fleet of one board with the single default tenant is a *zero-cost
//! wrapper*: `submit` routes to board 0 without scoring and the board
//! sees byte-identical submissions, so the event sequence, report and
//! digest are bit-identical to driving the `Scheduler` directly
//! (property-tested in `tests/properties.rs`). Likewise with no board
//! faults and no retry-after queue, `drain` degenerates to one pass of
//! per-board drains — the fault-free fleet is bit-identical to the
//! pre-resilience router (property-tested).

use crate::config::HeroConfig;
use crate::fault::{BoardFault, FaultPlan};
use crate::sched::report::percentile;
use crate::sched::{cache, place, policy, ClassReport, ServeReport};
use crate::sched::{JobDesc, JobHandle, JobOutcome, JobState, Policy, Priority, Scheduler};
use crate::trace::SchedEvent;
use anyhow::Result;
use std::collections::{HashMap, HashSet};
use std::fmt;

/// Index into the router's tenant table.
pub type TenantId = usize;

/// The tenant every untagged submission bills to (unlimited quotas, no
/// priority override — registered by [`Router::new`]).
pub const DEFAULT_TENANT: TenantId = 0;

/// Fleet-level async completion handle ([`Router::submit`]): an index in
/// global submission order, resolvable to the routed board's own
/// [`JobHandle`] state via [`Router::state`] / [`Router::poll`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FleetHandle(pub usize);

/// Cross-board routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    /// Minimize predicted finish across all boards' slots, including
    /// projected backlog and the compile cost a cache-cold board would
    /// pay (the default).
    #[default]
    Finish,
    /// Strict alternation over boards, blind to load and cache state —
    /// the baseline for the affinity studies.
    RoundRobin,
}

impl RoutePolicy {
    /// Parse a `--route` argument.
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s {
            "finish" | "predicted-finish" => Some(RoutePolicy::Finish),
            "round-robin" | "roundrobin" | "rr" => Some(RoutePolicy::RoundRobin),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RoutePolicy::Finish => "finish",
            RoutePolicy::RoundRobin => "round-robin",
        }
    }
}

/// One tenant's admission contract. A quota of 0 means unlimited.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantSpec {
    pub name: String,
    /// Most jobs this tenant may have admitted-and-unsettled at once
    /// (submission-time check; 0 = unlimited).
    pub max_in_flight: usize,
    /// Cap on the summed DRAM byte footprint of the tenant's in-flight
    /// jobs (0 = unlimited).
    pub max_resident_bytes: u64,
    /// Default QoS class for submissions that carry [`Priority::Normal`]
    /// (i.e. did not ask for a class themselves); `None` leaves
    /// submissions untouched.
    pub priority: Option<Priority>,
}

impl TenantSpec {
    /// An unlimited tenant with no priority override.
    pub fn unlimited(name: &str) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            max_in_flight: 0,
            max_resident_bytes: 0,
            priority: None,
        }
    }
}

/// Parse a `--tenants` specification: comma-separated
/// `name[:jobs[:bytes[:priority]]]` entries, where `jobs` caps in-flight
/// jobs, `bytes` caps resident bytes (both 0 or empty = unlimited) and
/// `priority` is a [`Priority::parse`] token. Example:
/// `batch:16:0:normal,interactive:0:0:high`.
pub fn parse_tenants(spec: &str) -> Result<Vec<TenantSpec>, String> {
    let mut out: Vec<TenantSpec> = Vec::new();
    for raw in spec.split(',') {
        let raw = raw.trim();
        let parts: Vec<&str> = raw.split(':').collect();
        if raw.is_empty() || parts[0].is_empty() || parts.len() > 4 {
            return Err(format!(
                "tenant entry {raw:?}: expected `name[:jobs[:bytes[:priority]]]`"
            ));
        }
        let name = parts[0].to_string();
        if out.iter().any(|t| t.name == name) {
            return Err(format!("duplicate tenant {name:?}"));
        }
        let number = |field: &str, what: &str| -> Result<u64, String> {
            field.parse().map_err(|_| format!("tenant {name:?}: bad {what} quota {field:?}"))
        };
        let max_in_flight = match parts.get(1) {
            None | Some(&"") => 0,
            Some(s) => number(s, "in-flight")? as usize,
        };
        let max_resident_bytes = match parts.get(2) {
            None | Some(&"") => 0,
            Some(s) => number(s, "resident-bytes")?,
        };
        let priority = match parts.get(3) {
            None | Some(&"") => None,
            Some(p) => Some(
                Priority::parse(p)
                    .ok_or_else(|| format!("tenant {name:?}: unknown priority {p:?}"))?,
            ),
        };
        out.push(TenantSpec { name, max_in_flight, max_resident_bytes, priority });
    }
    Ok(out)
}

/// Where a fleet submission went.
#[derive(Debug, Clone)]
enum Routed {
    /// Admitted and routed: the board index and that board's own handle.
    Board { board: usize, handle: JobHandle },
    /// Refused at the front tier by the tenant's quota — no board ever
    /// saw it.
    Quota { reason: String },
    /// Deferred in the front-tier retry-after queue ([`Router::with_queue`]):
    /// over quota at submission, waiting to be re-quoted once earlier jobs
    /// settle. The descriptor and its byte footprint ride along.
    Deferred { desc: JobDesc, bytes: u64 },
}

/// One fleet submission's record, in global submission order.
#[derive(Debug, Clone)]
struct FleetJob {
    tenant: TenantId,
    /// The class the job was submitted to its board with (tenant default
    /// already applied).
    priority: Priority,
    arrival: u64,
    routed: Routed,
}

/// Per-tenant admission accounting.
#[derive(Debug, Default)]
struct TenantStats {
    submitted: usize,
    admitted: usize,
    quota_rejected: usize,
    /// Admitted jobs not yet observed settled: `(board, handle, bytes)`.
    /// Swept lazily at each submission, so in-flight/resident figures are
    /// exact as of submission time.
    open: Vec<(usize, JobHandle, u64)>,
}

/// The front-tier router: N independent boards behind one submission API.
pub struct Router {
    boards: Vec<Scheduler>,
    route: RoutePolicy,
    tenants: Vec<TenantSpec>,
    stats: Vec<TenantStats>,
    jobs: Vec<FleetJob>,
    /// Per board, per slot: projected free cycle from jobs routed there
    /// but possibly not yet drained (floors the real port state).
    proj_free: Vec<Vec<u64>>,
    /// Per board: binary-cache keys of jobs routed there — the projection
    /// of what the board's cache will hold once it dispatches them.
    warm: Vec<HashSet<cache::BinKey>>,
    affinity_decisions: u64,
    affinity_hits: u64,
    rr_next: usize,
    /// Scheduled board failures ([`Router::with_faults`]), sorted by
    /// `(down_at, board)`; consumed by `drain`.
    kills: Vec<BoardFault>,
    /// Current health per board — routing skips unhealthy boards.
    healthy: Vec<bool>,
    /// Per board: health transitions `(cycle, healthy)` in drain order
    /// (empty = never failed). Surfaces in [`FleetReport::board_health`].
    health: Vec<Vec<(u64, bool)>>,
    /// Jobs evacuated off failed boards and resubmitted elsewhere.
    migrations: u64,
    /// Retry-after queue bound (0 = queue off: over-quota submissions are
    /// refused outright, the pre-resilience behavior).
    queue_depth: usize,
    /// Jobs currently deferred ([`Routed::Deferred`] entries in `jobs`).
    deferred: usize,
    /// Deferred submissions later admitted by a re-quote.
    queued_then_admitted: u64,
}

impl Router {
    /// Front N pre-built boards. Registers the unlimited default tenant
    /// (id [`DEFAULT_TENANT`]); routing defaults to
    /// [`RoutePolicy::Finish`].
    pub fn new(boards: Vec<Scheduler>) -> Router {
        assert!(!boards.is_empty(), "a fleet needs at least one board");
        let proj_free = boards.iter().map(|b| vec![0; b.pool().len()]).collect();
        let warm = boards.iter().map(|_| HashSet::new()).collect();
        let n = boards.len();
        Router {
            boards,
            route: RoutePolicy::Finish,
            tenants: vec![TenantSpec::unlimited("default")],
            stats: vec![TenantStats::default()],
            jobs: Vec::new(),
            proj_free,
            warm,
            affinity_decisions: 0,
            affinity_hits: 0,
            rr_next: 0,
            kills: Vec::new(),
            healthy: vec![true; n],
            health: vec![Vec::new(); n],
            migrations: 0,
            queue_depth: 0,
            deferred: 0,
            queued_then_admitted: 0,
        }
    }

    /// `boards` identical boards of `pool_per_board` instances each, FIFO
    /// dispatch — the [`crate::session::Session::fleet`] shape.
    pub fn homogeneous(cfg: &HeroConfig, boards: usize, pool_per_board: usize) -> Router {
        assert!(boards >= 1, "a fleet needs at least one board");
        Router::new(
            (0..boards)
                .map(|_| Scheduler::new(cfg.clone(), pool_per_board, Policy::Fifo))
                .collect(),
        )
    }

    /// Choose the routing policy (builder style).
    pub fn with_route(mut self, route: RoutePolicy) -> Router {
        self.route = route;
        self
    }

    /// Arm the plan's *board-level* failures on this fleet (builder
    /// style): each in-range `kill=B@C` takes board B down at cycle C
    /// during [`Router::drain`], with optional recovery. Instance-level
    /// fault rates apply per board via
    /// [`Scheduler::with_faults`](crate::sched::Scheduler::with_faults),
    /// not here. An empty plan changes nothing.
    pub fn with_faults(mut self, plan: &FaultPlan) -> Router {
        self.kills = plan.kills_for(self.boards.len());
        self
    }

    /// Arm the front-tier retry-after queue (builder style): up to
    /// `depth` over-quota submissions wait at the router instead of
    /// being refused, re-quoted as earlier jobs settle. Depth 0 keeps
    /// the queue off (refuse outright — the default).
    pub fn with_queue(mut self, depth: usize) -> Router {
        self.queue_depth = depth;
        self
    }

    pub fn route(&self) -> RoutePolicy {
        self.route
    }

    /// The boards, in index order (read-only; the router owns dispatch).
    pub fn boards(&self) -> &[Scheduler] {
        &self.boards
    }

    /// Board `i`'s scheduler, read-only.
    pub fn board(&self, i: usize) -> &Scheduler {
        &self.boards[i]
    }

    /// Register a tenant; its id tags submissions
    /// ([`Router::submit_for`]). Names must be unique across the fleet.
    pub fn tenant(&mut self, spec: TenantSpec) -> TenantId {
        assert!(
            self.tenants.iter().all(|t| t.name != spec.name),
            "duplicate tenant {:?}",
            spec.name
        );
        self.tenants.push(spec);
        self.stats.push(TenantStats::default());
        self.tenants.len() - 1
    }

    /// Find a tenant by name, or register it with unlimited quotas — the
    /// trace-replay path, where a `tenant` column names tenants on the
    /// fly.
    pub fn tenant_named(&mut self, name: &str) -> TenantId {
        match self.tenants.iter().position(|t| t.name == name) {
            Some(id) => id,
            None => self.tenant(TenantSpec::unlimited(name)),
        }
    }

    /// The registered tenant id for `name`, if any.
    pub fn tenant_id(&self, name: &str) -> Option<TenantId> {
        self.tenants.iter().position(|t| t.name == name)
    }

    /// Submit on the default tenant's account.
    pub fn submit(&mut self, desc: JobDesc) -> FleetHandle {
        self.submit_for(DEFAULT_TENANT, desc)
    }

    /// Submit a whole stream on the default tenant's account.
    pub fn submit_all(&mut self, descs: &[JobDesc]) -> Vec<FleetHandle> {
        descs.iter().map(|d| self.submit(*d)).collect()
    }

    /// Submit one job on `tenant`'s account: apply the tenant's default
    /// priority, check its quotas, and route across the fleet. Over-quota
    /// submissions settle immediately as rejected without touching any
    /// board.
    pub fn submit_for(&mut self, tenant: TenantId, mut desc: JobDesc) -> FleetHandle {
        assert!(tenant < self.tenants.len(), "unknown tenant id {tenant}");
        let id = self.jobs.len();
        // The tenant default applies only to submissions that did not ask
        // for a class themselves (Normal is the JobDesc default).
        if let (Priority::Normal, Some(p)) = (desc.priority, self.tenants[tenant].priority) {
            desc.priority = p;
        }
        self.sweep_settled(tenant);
        self.stats[tenant].submitted += 1;
        let bytes = desc.workload().map(|w| policy::job_bytes(&w)).unwrap_or(0);
        if let Some(reason) = self.quota_violation(tenant, bytes) {
            // Retry-after: defer instead of refusing, while the bounded
            // queue has room. Refusal becomes the overflow behavior.
            if self.deferred < self.queue_depth {
                self.deferred += 1;
                self.jobs.push(FleetJob {
                    tenant,
                    priority: desc.priority,
                    arrival: desc.arrival,
                    routed: Routed::Deferred { desc, bytes },
                });
                return FleetHandle(id);
            }
            self.stats[tenant].quota_rejected += 1;
            self.jobs.push(FleetJob {
                tenant,
                priority: desc.priority,
                arrival: desc.arrival,
                routed: Routed::Quota { reason },
            });
            return FleetHandle(id);
        }
        let board = self.route_board(&desc);
        let handle = self.boards[board].submit(desc);
        self.stats[tenant].admitted += 1;
        self.stats[tenant].open.push((board, handle, bytes));
        self.jobs.push(FleetJob {
            tenant,
            priority: desc.priority,
            arrival: desc.arrival,
            routed: Routed::Board { board, handle },
        });
        FleetHandle(id)
    }

    /// Drop settled jobs from the tenant's in-flight set, so quotas see
    /// exactly the jobs still admitted-and-unsettled at this submission.
    fn sweep_settled(&mut self, tenant: TenantId) {
        let boards = &self.boards;
        self.stats[tenant]
            .open
            .retain(|(b, h, _)| boards[*b].state(*h).map(|s| !s.settled()).unwrap_or(false));
    }

    fn quota_violation(&self, tenant: TenantId, bytes: u64) -> Option<String> {
        let spec = &self.tenants[tenant];
        let st = &self.stats[tenant];
        if spec.max_in_flight > 0 && st.open.len() >= spec.max_in_flight {
            return Some(format!(
                "tenant {:?} over in-flight quota ({} of {} jobs in flight)",
                spec.name,
                st.open.len(),
                spec.max_in_flight
            ));
        }
        if spec.max_resident_bytes > 0 {
            let resident: u64 = st.open.iter().map(|(_, _, b)| b).sum();
            if resident + bytes > spec.max_resident_bytes {
                return Some(format!(
                    "tenant {:?} over resident-bytes quota ({resident} + {bytes} B exceeds {} B)",
                    spec.name, spec.max_resident_bytes
                ));
            }
        }
        None
    }

    /// Pick the board for an admitted job. Single-board fleets
    /// short-circuit to board 0 — the degenerate-identity guarantee costs
    /// nothing and books no affinity decisions. Unhealthy boards are
    /// skipped by every policy (with all boards healthy — the only state
    /// possible before a fault plan is armed — the decisions are
    /// byte-identical to health-blind routing).
    fn route_board(&mut self, desc: &JobDesc) -> usize {
        if self.boards.len() == 1 {
            return 0;
        }
        match self.route {
            RoutePolicy::RoundRobin => {
                // Alternate as before, stepping over unhealthy boards
                // (bounded: some board is healthy or no routing happens).
                for _ in 0..self.boards.len() {
                    let b = self.rr_next % self.boards.len();
                    self.rr_next += 1;
                    if self.healthy[b] {
                        return b;
                    }
                }
                0
            }
            RoutePolicy::Finish => self.route_by_finish(desc),
        }
    }

    /// Cross-board predicted-finish routing. Per board, the score is the
    /// board's best slot under [`place::scores_from`] — the single-board
    /// placement engine, with slot starts floored by the router's
    /// projected occupancy — plus the predicted compile cost when the
    /// board is cold for the job's binary key. Minimal
    /// `(finish, stall, free, board, slot)` wins, the fleet-level
    /// extension of [`place::choose`]'s tie-breaks.
    fn route_by_finish(&mut self, desc: &JobDesc) -> usize {
        let Some(w) = desc.workload() else {
            // Unknown kernel: it will be rejected at the board; route to
            // the least-backlogged board so the rejection is deterministic.
            return self.least_loaded();
        };
        let dma_bytes = policy::job_bytes(&w);
        // (finish, stall, free, board, slot) of the best candidate.
        let mut best: Option<(u64, u64, u64, usize, usize)> = None;
        let mut best_warm = false;
        for (b, board) in self.boards.iter().enumerate() {
            if !self.healthy[b] {
                continue;
            }
            let cfg = board.config();
            let eff_threads = desc.threads.min(cfg.accel.cores_per_cluster as u32);
            let predicted = policy::predict_job(&w, desc.variant, eff_threads);
            let key = cache::key_for(cfg, &w, desc.variant, desc.threads);
            let warm = board.cache().contains(&key) || self.warm[b].contains(&key);
            let compile =
                if warm { 0 } else { cache::compile_cost_cycles(&w, desc.variant) };
            let pool = board.pool();
            for s in place::scores_from(
                pool,
                &self.proj_free[b],
                desc.arrival,
                predicted,
                dma_bytes,
                desc.priority.is_high(),
            ) {
                let free = pool.free_at(s.instance).max(self.proj_free[b][s.instance]);
                let cand = (s.finish + compile, s.stall, free, b, s.instance);
                let better = match best {
                    None => true,
                    Some(cur) => cand < cur,
                };
                if better {
                    best = Some(cand);
                    best_warm = warm;
                }
            }
        }
        let (finish, _, _, b, slot) = best.expect("some board is healthy (caller-checked)");
        self.affinity_decisions += 1;
        if best_warm {
            self.affinity_hits += 1;
        }
        // Project the routed job's occupancy (compile included — it runs
        // on the slot) and the binary its dispatch will warm.
        self.proj_free[b][slot] = self.proj_free[b][slot].max(finish);
        let w = desc.workload().expect("checked above");
        let key = cache::key_for(self.boards[b].config(), &w, desc.variant, desc.threads);
        self.warm[b].insert(key);
        b
    }

    /// The healthy board whose earliest slot (projected) frees first;
    /// ties break toward the lowest index.
    fn least_loaded(&self) -> usize {
        (0..self.boards.len())
            .filter(|&b| self.healthy[b])
            .min_by_key(|&b| {
                let pool = self.boards[b].pool();
                (0..pool.len())
                    .map(|i| pool.free_at(i).max(self.proj_free[b][i]))
                    .min()
                    .unwrap_or(0)
            })
            .unwrap_or(0)
    }

    /// Drain every board to completion. With no board faults and no
    /// retry-after queue this is one pass of per-board drains in board
    /// order (boards are independent simulations — order does not change
    /// any board's events), bit-identical to the pre-resilience router.
    /// Scheduled board failures are processed first, at their kill
    /// cycles (evacuation + re-routing, then recovery); deferred
    /// submissions are re-quoted between passes until no progress
    /// remains, and whatever stays blocked settles as quota-refused.
    pub fn drain(&mut self) -> Result<()> {
        self.process_kills()?;
        loop {
            for b in &mut self.boards {
                b.drain()?;
            }
            if self.pump_deferred() == 0 {
                break;
            }
        }
        self.finalize_deferred();
        Ok(())
    }

    /// Take each scheduled board failure in `(down_at, board)` order:
    /// advance the dying board to its failure cycle (work whose slot
    /// freed before the failure dispatches and completes — the board's
    /// front-end dies, its fabric finishes assigned windows), mark it
    /// unhealthy, evacuate its queued named jobs onto surviving boards
    /// (health-aware re-route through the normal scoring, fleet handles
    /// remapped so they keep resolving), then process recoveries.
    fn process_kills(&mut self) -> Result<()> {
        let kills = std::mem::take(&mut self.kills);
        for k in &kills {
            self.boards[k.board].step_until(k.down_at)?;
            self.healthy[k.board] = false;
            self.health[k.board].push((k.down_at, false));
            self.boards[k.board]
                .trace
                .record(SchedEvent::BoardDown { board: k.board, at: k.down_at });
            for (handle, mut desc) in self.boards[k.board].evacuate() {
                // The job re-enters the fleet at the failure point: it
                // cannot start elsewhere before the failure displaced it.
                desc.arrival = desc.arrival.max(k.down_at);
                if !self.healthy.iter().any(|&h| h) {
                    self.boards[k.board].fail_evacuated(
                        handle,
                        "board failed and no healthy board remains".to_string(),
                    );
                    continue;
                }
                let to = self.route_board(&desc);
                let new_handle = self.boards[to].submit(desc);
                self.boards[k.board].trace.record(SchedEvent::Migrated {
                    job: handle.0,
                    from: k.board,
                    to,
                    at: k.down_at,
                });
                self.boards[k.board].mark_migrated(handle);
                self.migrations += 1;
                self.remap(k.board, handle, to, new_handle);
            }
        }
        // Recoveries, in cycle order: the board rejoins the healthy set,
        // so later routing (deferred re-quotes, future submissions) sees
        // it again.
        let mut ups: Vec<(u64, usize)> =
            kills.iter().filter_map(|k| k.up_at.map(|c| (c, k.board))).collect();
        ups.sort_unstable();
        for (at, board) in ups {
            self.healthy[board] = true;
            self.health[board].push((at, true));
            self.boards[board].trace.record(SchedEvent::BoardUp { board, at });
        }
        Ok(())
    }

    /// Point the fleet-level record of an evacuated job at its new
    /// board, so `state`/`poll` and the digest chain follow the job; the
    /// tenant's in-flight entry moves with it (same bytes, new board).
    fn remap(&mut self, from: usize, old: JobHandle, to: usize, new: JobHandle) {
        let fj = self
            .jobs
            .iter_mut()
            .find(|j| {
                matches!(j.routed, Routed::Board { board, handle }
                    if board == from && handle == old)
            })
            .expect("evacuated jobs were fleet-routed");
        let tenant = fj.tenant;
        fj.routed = Routed::Board { board: to, handle: new };
        for entry in &mut self.stats[tenant].open {
            if entry.0 == from && entry.1 == old {
                *entry = (to, new, entry.2);
            }
        }
    }

    /// Re-quote deferred submissions in submission order against their
    /// tenants' live quotas; admit those that now fit, with the same
    /// routing as a fresh submission. A still-blocked tenant's job keeps
    /// waiting without blocking other tenants behind it. Returns the
    /// number admitted.
    fn pump_deferred(&mut self) -> usize {
        let mut admitted = 0;
        for id in 0..self.jobs.len() {
            let Routed::Deferred { desc, bytes } = self.jobs[id].routed.clone() else {
                continue;
            };
            let tenant = self.jobs[id].tenant;
            self.sweep_settled(tenant);
            if self.quota_violation(tenant, bytes).is_some() {
                continue;
            }
            let board = self.route_board(&desc);
            let handle = self.boards[board].submit(desc);
            self.stats[tenant].admitted += 1;
            self.stats[tenant].open.push((board, handle, bytes));
            self.jobs[id].routed = Routed::Board { board, handle };
            self.deferred -= 1;
            self.queued_then_admitted += 1;
            admitted += 1;
        }
        admitted
    }

    /// End of drain: whatever is still deferred cannot be admitted by
    /// any further progress — settle it as quota-refused.
    fn finalize_deferred(&mut self) {
        for j in &mut self.jobs {
            if matches!(j.routed, Routed::Deferred { .. }) {
                let name = &self.tenants[j.tenant].name;
                self.stats[j.tenant].quota_rejected += 1;
                j.routed = Routed::Quota {
                    reason: format!(
                        "tenant {name:?} still over quota when the fleet drained \
                         (retry-after queue)"
                    ),
                };
                self.deferred -= 1;
            }
        }
    }

    /// Jobs submitted to the fleet (including quota-rejected ones).
    pub fn submitted(&self) -> usize {
        self.jobs.len()
    }

    /// Current state of a fleet handle (owned — quota rejections are
    /// synthesized at the front tier, board states are cloned). `None`
    /// for a handle this router never issued.
    pub fn state(&self, h: FleetHandle) -> Option<JobState> {
        match &self.jobs.get(h.0)?.routed {
            Routed::Quota { reason } => Some(JobState::Rejected { reason: reason.clone() }),
            Routed::Board { board, handle } => self.boards[*board].state(*handle).cloned(),
            // Waiting at the front tier: queued, just not on a board yet.
            Routed::Deferred { .. } => Some(JobState::Queued),
        }
    }

    /// Completion record of a fleet handle, if its job finished.
    pub fn poll(&self, h: FleetHandle) -> Option<&JobOutcome> {
        match &self.jobs.get(h.0)?.routed {
            Routed::Board { board, handle } => self.boards[*board].poll(*handle),
            Routed::Quota { .. } | Routed::Deferred { .. } => None,
        }
    }

    /// Render all boards' event logs interleaved on one timeline, each
    /// line prefixed with its board id. Events inherit the cycle of the
    /// last timed event on their board (clamped non-decreasing), so
    /// untimed submit/compile lines stay next to the dispatch they belong
    /// to; ties order by board index, then per-board log order.
    pub fn events(&self) -> String {
        let mut entries: Vec<(u64, usize, usize, String)> = Vec::new();
        for (b, board) in self.boards.iter().enumerate() {
            let mut clock = 0u64;
            for (seq, e) in board.trace.events.iter().enumerate() {
                if let Some(c) = e.cycle() {
                    clock = clock.max(c);
                }
                entries.push((clock, b, seq, format!("[b{b}] {}", e.render_line())));
            }
        }
        entries.sort_by(|x, y| (x.0, x.1, x.2).cmp(&(y.0, y.1, y.2)));
        let mut out = String::new();
        for (_, _, _, line) in entries {
            out.push_str(&line);
            out.push('\n');
        }
        out
    }

    /// Merged fleet report: per-board [`ServeReport`]s, per-tenant
    /// per-class turnaround percentiles, affinity hit rate, and a digest
    /// chained over completed jobs in *global submission order* — so two
    /// runs of one stream under different routing policies digest
    /// identically iff their numerics match job for job.
    pub fn report(&self) -> FleetReport {
        let boards: Vec<ServeReport> = self.boards.iter().map(|b| b.report()).collect();
        let mut digest = 0xcbf2_9ce4_8422_2325u64;
        let mut completed = 0usize;
        let mut quota_rejected = 0usize;
        let mut queued = 0usize;
        // Per tenant, per class (Normal = 0, High = 1): turnaround
        // samples and preemption counts.
        let mut samples: Vec<[Vec<u64>; 2]> =
            (0..self.tenants.len()).map(|_| [Vec::new(), Vec::new()]).collect();
        let mut preempted: Vec<[u64; 2]> = vec![[0, 0]; self.tenants.len()];
        let mut owner: HashMap<(usize, usize), (TenantId, usize)> = HashMap::new();
        for j in &self.jobs {
            let class = j.priority.is_high() as usize;
            match &j.routed {
                Routed::Quota { .. } => quota_rejected += 1,
                Routed::Deferred { .. } => queued += 1,
                Routed::Board { board, handle } => {
                    owner.insert((*board, handle.0), (j.tenant, class));
                    if let Some(o) = self.boards[*board].poll(*handle) {
                        completed += 1;
                        digest = (digest ^ o.digest).wrapping_mul(0x0000_0100_0000_01b3);
                        samples[j.tenant][class].push(o.end.saturating_sub(j.arrival));
                    }
                }
            }
        }
        for (b, board) in self.boards.iter().enumerate() {
            for e in &board.trace.events {
                if let SchedEvent::Preempted { job, .. } = e {
                    if let Some(&(t, class)) = owner.get(&(b, *job)) {
                        preempted[t][class] += 1;
                    }
                }
            }
        }
        let mut tenants = Vec::with_capacity(self.tenants.len());
        for (t, spec) in self.tenants.iter().enumerate() {
            let mut classes = Vec::new();
            for (c, p) in [Priority::Normal, Priority::High].into_iter().enumerate() {
                let v = &mut samples[t][c];
                if v.is_empty() {
                    continue;
                }
                v.sort_unstable();
                classes.push(ClassReport {
                    priority: p,
                    jobs: v.len(),
                    preempted: preempted[t][c],
                    p50_turnaround_cycles: percentile(v, 50),
                    p95_turnaround_cycles: percentile(v, 95),
                });
            }
            tenants.push(TenantReport {
                name: spec.name.clone(),
                submitted: self.stats[t].submitted,
                admitted: self.stats[t].admitted,
                quota_rejected: self.stats[t].quota_rejected,
                classes,
            });
        }
        FleetReport {
            route: self.route.label(),
            submitted: self.jobs.len(),
            admitted: self.jobs.len() - quota_rejected - queued,
            quota_rejected,
            queued,
            queued_then_admitted: self.queued_then_admitted,
            completed,
            rejected: boards.iter().map(|r| r.rejected).sum(),
            makespan_cycles: boards.iter().map(|r| r.makespan_cycles).max().unwrap_or(0),
            affinity_decisions: self.affinity_decisions,
            affinity_hits: self.affinity_hits,
            faults: boards
                .iter()
                .map(|r| r.faults_transient + r.faults_timeout + r.faults_deadline)
                .sum(),
            retries: boards.iter().map(|r| r.retries).sum(),
            migrations: self.migrations,
            board_health: self.health.clone(),
            digest,
            tenants,
            boards,
        }
    }
}

/// One tenant's slice of a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub name: String,
    pub submitted: usize,
    pub admitted: usize,
    /// Submissions refused at the front tier by this tenant's quotas.
    pub quota_rejected: usize,
    /// Turnaround percentiles per QoS class (classes with completed jobs
    /// only; `Normal` first, then `High`) — same shape as
    /// [`ServeReport::classes`].
    pub classes: Vec<ClassReport>,
}

impl TenantReport {
    /// The class summary for `priority`, if any of its jobs completed.
    pub fn class(&self, priority: Priority) -> Option<&ClassReport> {
        self.classes.iter().find(|c| c.priority == priority)
    }
}

/// A whole fleet run's merged outcome ([`Router::report`]).
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Routing policy label ([`RoutePolicy::label`]).
    pub route: &'static str,
    /// Fleet-level submissions (including quota-rejected ones).
    pub submitted: usize,
    /// Submissions that passed tenant admission and reached a board.
    pub admitted: usize,
    /// Submissions refused at the front tier by tenant quotas.
    pub quota_rejected: usize,
    /// Submissions still waiting in the retry-after queue (0 after a
    /// drain — leftovers settle as quota-refused).
    pub queued: usize,
    /// Deferred submissions later admitted by a re-quote
    /// ([`Router::with_queue`]).
    pub queued_then_admitted: u64,
    /// Completed across all boards (fleet-routed jobs; a capacity-split
    /// child counts on its board, not here).
    pub completed: usize,
    /// Board-level rejections across the fleet (admission control,
    /// unknown kernels, compile errors).
    pub rejected: usize,
    /// Max over the boards' makespans — the fleet drains when its
    /// slowest board does.
    pub makespan_cycles: u64,
    /// Finish-routing decisions taken (0 under round-robin or a
    /// single-board fleet).
    pub affinity_decisions: u64,
    /// Of those, routes that landed on a board already warm for the
    /// job's binary.
    pub affinity_hits: u64,
    /// Detected faults summed over the boards (transient + timeout +
    /// deadline — see [`ServeReport`]'s per-kind counters).
    pub faults: u64,
    /// Retry attempts summed over the boards.
    pub retries: u64,
    /// Jobs evacuated off failed boards and completed elsewhere.
    pub migrations: u64,
    /// Per board: health transitions `(cycle, healthy)` in drain order
    /// (empty = the board never failed).
    pub board_health: Vec<Vec<(u64, bool)>>,
    /// Digest over completed jobs' output digests in global submission
    /// order — routing-invariant on homogeneous boards.
    pub digest: u64,
    pub tenants: Vec<TenantReport>,
    pub boards: Vec<ServeReport>,
}

impl FleetReport {
    /// Warm-board fraction of finish-routing decisions (0.0 when none
    /// were taken).
    pub fn affinity_hit_rate(&self) -> f64 {
        if self.affinity_decisions == 0 {
            0.0
        } else {
            self.affinity_hits as f64 / self.affinity_decisions as f64
        }
    }

    /// The report slice for a tenant by name.
    pub fn tenant(&self, name: &str) -> Option<&TenantReport> {
        self.tenants.iter().find(|t| t.name == name)
    }
}

impl fmt::Display for FleetReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "fleet         : {} board(s), route {}", self.boards.len(), self.route)?;
        writeln!(
            f,
            "jobs          : {} submitted, {} admitted, {} quota-rejected, {} completed, \
             {} rejected",
            self.submitted, self.admitted, self.quota_rejected, self.completed, self.rejected
        )?;
        writeln!(f, "makespan      : {} cycles (slowest board)", self.makespan_cycles)?;
        if self.affinity_decisions > 0 {
            writeln!(
                f,
                "affinity      : {}/{} routes to a warm board ({:.1}%)",
                self.affinity_hits,
                self.affinity_decisions,
                100.0 * self.affinity_hit_rate()
            )?;
        }
        // Resilience lines render only when something happened, so the
        // fault-free report stays byte-identical to the pre-resilience one.
        if self.faults > 0 || self.retries > 0 || self.migrations > 0 {
            writeln!(
                f,
                "resilience    : {} fault(s), {} retry(ies), {} migration(s)",
                self.faults, self.retries, self.migrations
            )?;
        }
        if self.queued_then_admitted > 0 || self.queued > 0 {
            writeln!(
                f,
                "retry-after   : {} deferred admission(s), {} still queued",
                self.queued_then_admitted, self.queued
            )?;
        }
        for (b, timeline) in self.board_health.iter().enumerate() {
            if timeline.is_empty() {
                continue;
            }
            let spans: Vec<String> = timeline
                .iter()
                .map(|(c, up)| format!("{}@{c}", if *up { "up" } else { "down" }))
                .collect();
            writeln!(f, "health b{b:<5}: {}", spans.join(", "))?;
        }
        for t in &self.tenants {
            writeln!(
                f,
                "tenant {:<8}: {:>4} submitted, {:>4} admitted, {:>4} quota-rejected",
                t.name, t.submitted, t.admitted, t.quota_rejected
            )?;
            for c in &t.classes {
                writeln!(
                    f,
                    "  class {:<6}: {:>4} jobs, turnaround p50 {:>12} cy, p95 {:>12} cy",
                    c.priority.label(),
                    c.jobs,
                    c.p50_turnaround_cycles,
                    c.p95_turnaround_cycles
                )?;
            }
        }
        for (i, r) in self.boards.iter().enumerate() {
            let busy: u64 = r.instances.iter().map(|inst| inst.busy_cycles).sum();
            let slots = r.makespan_cycles * r.instances.len() as u64;
            let util = if slots == 0 { 0.0 } else { busy as f64 / slots as f64 };
            writeln!(
                f,
                "board {:>3}     : {:>4} completed, makespan {:>12} cy, util {:>5.1}%, \
                 dram stall {:>10} cy",
                i,
                r.completed,
                r.makespan_cycles,
                100.0 * util,
                r.dram_stall_cycles
            )?;
        }
        write!(f, "fleet digest  : {:#018x}", self.digest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::aurora;
    use crate::workloads::synth;

    fn job(kernel: &'static str, size: usize, seed: u64) -> JobDesc {
        JobDesc {
            kernel,
            size,
            variant: crate::bench_harness::Variant::Handwritten,
            threads: 8,
            seed,
            arrival: 0,
            priority: Priority::Normal,
        }
    }

    #[test]
    fn route_policy_parses_and_labels() {
        assert_eq!(RoutePolicy::parse("finish"), Some(RoutePolicy::Finish));
        assert_eq!(RoutePolicy::parse("predicted-finish"), Some(RoutePolicy::Finish));
        assert_eq!(RoutePolicy::parse("round-robin"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("random"), None);
        assert_eq!(RoutePolicy::default(), RoutePolicy::Finish);
        assert_eq!(RoutePolicy::RoundRobin.label(), "round-robin");
    }

    #[test]
    fn tenant_spec_parses_quotas_and_rejects_garbage() {
        let ts = parse_tenants("batch:16:4096:normal,interactive:::hi,free").unwrap();
        assert_eq!(ts.len(), 3);
        assert_eq!(
            ts[0],
            TenantSpec {
                name: "batch".into(),
                max_in_flight: 16,
                max_resident_bytes: 4096,
                priority: Some(Priority::Normal),
            }
        );
        assert_eq!(ts[1].max_in_flight, 0, "empty fields mean unlimited");
        assert_eq!(ts[1].priority, Some(Priority::High));
        assert_eq!(ts[2], TenantSpec::unlimited("free"));
        assert!(parse_tenants("").unwrap_err().contains("tenant entry"));
        assert!(parse_tenants("a,,b").unwrap_err().contains("tenant entry"));
        assert!(parse_tenants("a:x").unwrap_err().contains("bad in-flight"));
        assert!(parse_tenants("a:1:y").unwrap_err().contains("bad resident-bytes"));
        assert!(parse_tenants("a:1:2:urgent").unwrap_err().contains("unknown priority"));
        assert!(parse_tenants("a:1:2:hi:extra").unwrap_err().contains("tenant entry"));
        assert!(parse_tenants("a,a").unwrap_err().contains("duplicate"));
    }

    #[test]
    fn fleet_of_one_matches_plain_scheduler_events() {
        let jobs = synth::tiny_jobs(12, 41);
        let mut solo = Scheduler::new(aurora(), 2, Policy::Sjf);
        let mut fleet = Router::new(vec![Scheduler::new(aurora(), 2, Policy::Sjf)]);
        for d in &jobs {
            solo.submit(*d);
            fleet.submit(*d);
        }
        solo.drain().unwrap();
        fleet.drain().unwrap();
        assert_eq!(solo.trace.events, fleet.board(0).trace.events);
        let (rs, rf) = (solo.report(), fleet.report());
        assert_eq!(rs.digest, rf.digest, "fleet digest chain matches a single board's");
        assert_eq!(rs.makespan_cycles, rf.makespan_cycles);
        assert_eq!(rf.affinity_decisions, 0, "degenerate fleets never score");
    }

    #[test]
    fn in_flight_quota_caps_a_burst_and_frees_after_drain() {
        let mut r = Router::new(vec![Scheduler::new(aurora(), 1, Policy::Fifo)]);
        let t = r.tenant(TenantSpec {
            name: "capped".into(),
            max_in_flight: 2,
            max_resident_bytes: 0,
            priority: None,
        });
        let h: Vec<FleetHandle> =
            (0..3).map(|i| r.submit_for(t, job("gemm", 8, i as u64))).collect();
        assert!(matches!(r.state(h[1]), Some(JobState::Queued)));
        match r.state(h[2]) {
            Some(JobState::Rejected { reason }) => {
                assert!(reason.contains("in-flight quota"), "{reason}")
            }
            s => panic!("third submission must be quota-rejected, got {s:?}"),
        }
        assert_eq!(r.board(0).submitted(), 2, "rejected job never reached the board");
        r.drain().unwrap();
        // Settled jobs leave the in-flight set: the tenant can burst again.
        let h4 = r.submit_for(t, job("gemm", 8, 9));
        assert!(matches!(r.state(h4), Some(JobState::Queued)));
        let rep = r.report();
        let t = rep.tenant("capped").unwrap();
        assert_eq!((t.submitted, t.admitted, t.quota_rejected), (4, 3, 1));
        assert_eq!(rep.quota_rejected, 1);
    }

    #[test]
    fn resident_bytes_quota_counts_in_flight_footprints() {
        let w = job("gemm", 8, 0).workload().unwrap();
        let bytes = policy::job_bytes(&w);
        let mut r = Router::new(vec![Scheduler::new(aurora(), 1, Policy::Fifo)]);
        let t = r.tenant(TenantSpec {
            name: "lean".into(),
            max_in_flight: 0,
            max_resident_bytes: bytes, // exactly one job fits
            priority: None,
        });
        let first = r.submit_for(t, job("gemm", 8, 1));
        assert!(matches!(r.state(first), Some(JobState::Queued)));
        let second = r.submit_for(t, job("gemm", 8, 2));
        match r.state(second) {
            Some(JobState::Rejected { reason }) => {
                assert!(reason.contains("resident-bytes"), "{reason}")
            }
            s => panic!("second job exceeds the byte quota, got {s:?}"),
        }
    }

    #[test]
    fn tenant_default_priority_applies_to_unmarked_jobs_only() {
        let mut r = Router::new(vec![Scheduler::new(aurora(), 1, Policy::Fifo)]);
        let t = r.tenant(TenantSpec {
            name: "interactive".into(),
            max_in_flight: 0,
            max_resident_bytes: 0,
            priority: Some(Priority::High),
        });
        r.submit_for(t, job("gemm", 8, 1));
        let mut high = job("atax", 12, 2);
        high.priority = Priority::High;
        r.submit(high); // default tenant: no override, stays as marked
        r.submit(job("bicg", 12, 3)); // default tenant: stays Normal
        let events = &r.board(0).trace.events;
        assert_eq!(
            events[0],
            SchedEvent::Submitted { job: 0, priority: Priority::High },
            "tenant default upgraded the unmarked job"
        );
        assert_eq!(events[1], SchedEvent::Submitted { job: 1, priority: Priority::High });
        assert_eq!(events[2], SchedEvent::Submitted { job: 2, priority: Priority::Normal });
    }

    #[test]
    fn finish_routing_concentrates_repeated_kernels_on_warm_boards() {
        // Two kernels, two jobs each, two boards of two slots: the first
        // job of each kernel warms a board, and the repeat lands on that
        // board's idle second slot instead of paying a compile elsewhere.
        let mut r = Router::homogeneous(&aurora(), 2, 2);
        for d in
            [job("gemm", 8, 1), job("gemm", 8, 2), job("atax", 12, 3), job("atax", 12, 4)]
        {
            r.submit(d);
        }
        r.drain().unwrap();
        let rep = r.report();
        assert_eq!(rep.completed, 4);
        assert_eq!(rep.affinity_decisions, 4);
        assert_eq!(rep.affinity_hits, 2, "each kernel's repeat hit its warm board");
        let misses: u64 = rep.boards.iter().map(|b| b.cache_misses).sum();
        assert_eq!(misses, 2, "one lowering per kernel across the whole fleet");
        // Each kernel's pair landed on a single board (2 jobs per board).
        assert!(rep.boards.iter().all(|b| b.completed == 2), "load stayed balanced");
    }

    #[test]
    fn merged_events_carry_board_ids_on_one_timeline() {
        let mut r = Router::homogeneous(&aurora(), 2, 1);
        r.submit_all(&[job("gemm", 8, 1), job("gemm", 8, 2), job("atax", 12, 3)]);
        r.drain().unwrap();
        let merged = r.events();
        let per_board: usize = r.boards().iter().map(|b| b.trace.events.len()).sum();
        assert_eq!(merged.lines().count(), per_board, "every event renders exactly once");
        assert!(merged.lines().any(|l| l.starts_with("[b0] ")), "{merged}");
        assert!(merged.lines().any(|l| l.starts_with("[b1] ")), "{merged}");
        // The per-board monotone clocks interleave: once both boards have
        // dispatched, completion lines sort by cycle, not by board.
        let report = r.report();
        assert!(report.to_string().contains("fleet digest"), "report renders");
    }

    #[test]
    fn board_kill_evacuates_queued_jobs_and_loses_nothing() {
        let jobs: Vec<JobDesc> = (0..8)
            .map(|i| job(if i % 2 == 0 { "gemm" } else { "atax" }, 8 + 4 * (i % 2), i as u64))
            .collect();
        // Batching off so same-kernel jobs dispatch one at a time and the
        // dying board still holds a queue at its kill cycle.
        let board = || Scheduler::new(aurora(), 1, Policy::Fifo).with_batching(false);
        // Fault-free reference: same stream, same fleet shape.
        let mut healthy = Router::new(vec![board(), board()]);
        for d in &jobs {
            healthy.submit(*d);
        }
        healthy.drain().unwrap();
        // Board 1 dies at cycle 1: whatever its slot started by then
        // completes, the queued remainder evacuates to board 0.
        let plan = crate::fault::parse("kill=1@1").unwrap();
        let mut r = Router::new(vec![board(), board()]).with_faults(&plan);
        let h: Vec<FleetHandle> = jobs.iter().map(|d| r.submit(*d)).collect();
        r.drain().unwrap();
        for (i, hi) in h.iter().enumerate() {
            assert!(
                matches!(r.state(*hi), Some(JobState::Done(_))),
                "job {i} must survive the board failure: {:?}",
                r.state(*hi)
            );
        }
        let (rep, rep_ref) = (r.report(), healthy.report());
        assert_eq!(rep.completed, jobs.len(), "no job may be lost to the failure");
        assert_eq!(
            rep.digest, rep_ref.digest,
            "failure moves jobs and time, never numerics"
        );
        assert!(rep.migrations > 0, "board 1 had queued jobs to evacuate");
        assert_eq!(
            rep.migrations,
            rep.boards[1].migrated,
            "fleet and board accounting agree"
        );
        assert_eq!(rep.board_health[1], vec![(1, false)]);
        assert!(rep.board_health[0].is_empty(), "board 0 never failed");
        let events = r.events();
        assert!(events.contains("down      board 1 unhealthy at cycle 1"), "{events}");
        assert!(events.contains("board 1 -> board 0"), "{events}");
        let shown = rep.to_string();
        assert!(shown.contains("migration(s)"), "{shown}");
        assert!(shown.contains("health b1    : down@1"), "{shown}");
    }

    #[test]
    fn board_recovery_rejoins_the_healthy_set() {
        let plan = crate::fault::parse("kill=1@1,recover=1@50000000").unwrap();
        let mut r = Router::homogeneous(&aurora(), 2, 1).with_faults(&plan);
        for i in 0..4 {
            r.submit(job("gemm", 8, i));
        }
        r.drain().unwrap();
        let rep = r.report();
        assert_eq!(rep.completed, 4);
        assert_eq!(rep.board_health[1], vec![(1, false), (50_000_000, true)]);
        assert!(r.events().contains("up        board 1 recovered at cycle 50000000"));
    }

    #[test]
    fn retry_after_queue_defers_then_admits_instead_of_refusing() {
        let mut r =
            Router::new(vec![Scheduler::new(aurora(), 1, Policy::Fifo)]).with_queue(8);
        let t = r.tenant(TenantSpec {
            name: "capped".into(),
            max_in_flight: 2,
            max_resident_bytes: 0,
            priority: None,
        });
        let h: Vec<FleetHandle> =
            (0..5).map(|i| r.submit_for(t, job("gemm", 8, i as u64))).collect();
        // Beyond the quota the submissions wait at the front tier.
        assert!(matches!(r.state(h[2]), Some(JobState::Queued)));
        assert!(matches!(r.state(h[4]), Some(JobState::Queued)));
        assert_eq!(r.board(0).submitted(), 2, "deferred jobs reached no board yet");
        r.drain().unwrap();
        for hi in &h {
            assert!(matches!(r.state(*hi), Some(JobState::Done(_))), "{:?}", r.state(*hi));
        }
        let rep = r.report();
        assert_eq!(rep.queued_then_admitted, 3, "all three deferred jobs were admitted");
        assert_eq!(rep.queued, 0, "nothing left waiting after a drain");
        let tr = rep.tenant("capped").unwrap();
        assert_eq!((tr.submitted, tr.admitted, tr.quota_rejected), (5, 5, 0));
        assert!(rep.to_string().contains("retry-after   : 3 deferred admission(s)"));
    }

    #[test]
    fn retry_after_queue_overflow_still_refuses() {
        let mut r =
            Router::new(vec![Scheduler::new(aurora(), 1, Policy::Fifo)]).with_queue(1);
        let t = r.tenant(TenantSpec {
            name: "capped".into(),
            max_in_flight: 1,
            max_resident_bytes: 0,
            priority: None,
        });
        let h: Vec<FleetHandle> =
            (0..3).map(|i| r.submit_for(t, job("gemm", 8, i as u64))).collect();
        assert!(matches!(r.state(h[1]), Some(JobState::Queued)), "deferred");
        match r.state(h[2]) {
            Some(JobState::Rejected { reason }) => {
                assert!(reason.contains("in-flight quota"), "{reason}")
            }
            s => panic!("queue overflow must refuse, got {s:?}"),
        }
    }

    #[test]
    fn empty_fault_plan_and_queue_off_change_nothing() {
        let jobs = synth::tiny_jobs(10, 97);
        let mut plain = Router::homogeneous(&aurora(), 2, 1);
        let mut armed = Router::homogeneous(&aurora(), 2, 1)
            .with_faults(&crate::fault::FaultPlan::default())
            .with_queue(0);
        for d in &jobs {
            plain.submit(*d);
            armed.submit(*d);
        }
        plain.drain().unwrap();
        armed.drain().unwrap();
        assert_eq!(plain.events(), armed.events(), "defaults must be bit-identical");
        assert_eq!(plain.report().digest, armed.report().digest);
    }

    #[test]
    fn round_robin_alternates_and_digests_match_finish_routing() {
        let jobs: Vec<JobDesc> = (0..6).map(|i| job("gemm", 8, i as u64)).collect();
        let mut rr = Router::homogeneous(&aurora(), 2, 1).with_route(RoutePolicy::RoundRobin);
        let mut fin = Router::homogeneous(&aurora(), 2, 1);
        for d in &jobs {
            rr.submit(*d);
            fin.submit(*d);
        }
        rr.drain().unwrap();
        fin.drain().unwrap();
        let (rep_rr, rep_fin) = (rr.report(), fin.report());
        assert_eq!(rep_rr.route, "round-robin");
        assert_eq!(rep_rr.affinity_decisions, 0, "round-robin never scores");
        assert_eq!(rep_rr.boards[0].completed, 3, "strict alternation");
        assert_eq!(rep_rr.boards[1].completed, 3);
        assert_eq!(
            rep_rr.digest, rep_fin.digest,
            "routing moves time, never numerics: digests are routing-invariant"
        );
    }
}
