//! Assembly-style pretty printer for [`Inst`] and [`Program`].
//!
//! Used by `hero disasm`, the Fig 9 inner-loop analysis, and test
//! diagnostics. The syntax follows RISC-V assembly with `p.`-prefixed
//! Xpulpv2 mnemonics, matching the paper's §3.4 discussion.

use super::*;
use std::fmt::Write as _;

fn alu_name(op: AluOp) -> &'static str {
    match op {
        AluOp::Add => "add",
        AluOp::Sub => "sub",
        AluOp::Mul => "mul",
        AluOp::Mulhu => "mulhu",
        AluOp::Div => "div",
        AluOp::Rem => "rem",
        AluOp::And => "and",
        AluOp::Or => "or",
        AluOp::Xor => "xor",
        AluOp::Sll => "sll",
        AluOp::Srl => "srl",
        AluOp::Sra => "sra",
        AluOp::Slt => "slt",
        AluOp::Sltu => "sltu",
        AluOp::Min => "p.min",
        AluOp::Max => "p.max",
    }
}

fn fp_name(op: FpOp) -> &'static str {
    match op {
        FpOp::Add => "fadd.s",
        FpOp::Sub => "fsub.s",
        FpOp::Mul => "fmul.s",
        FpOp::Div => "fdiv.s",
        FpOp::Min => "fmin.s",
        FpOp::Max => "fmax.s",
    }
}

fn cond_name(c: Cond) -> &'static str {
    match c {
        Cond::Eq => "beq",
        Cond::Ne => "bne",
        Cond::Lt => "blt",
        Cond::Ge => "bge",
        Cond::Ltu => "bltu",
        Cond::Geu => "bgeu",
    }
}

fn csr_name(c: Csr) -> &'static str {
    match c {
        Csr::MHartId => "mhartid",
        Csr::MClusterId => "mclusterid",
        Csr::MNumCores => "mnumcores",
        Csr::ExtAddr => "extaddr",
        Csr::MCycle => "mcycle",
    }
}

/// Render one instruction.
pub fn inst(i: &Inst) -> String {
    match *i {
        Inst::Li { rd, imm } => format!("li x{rd}, {imm}"),
        Inst::AluImm { op, rd, rs1, imm } => {
            format!("{}i x{rd}, x{rs1}, {imm}", alu_name(op))
        }
        Inst::Alu { op, rd, rs1, rs2 } => format!("{} x{rd}, x{rs1}, x{rs2}", alu_name(op)),
        Inst::Lw { rd, rs1, offset } => format!("lw x{rd}, {offset}(x{rs1})"),
        Inst::Sw { rs2, rs1, offset } => format!("sw x{rs2}, {offset}(x{rs1})"),
        Inst::Branch { cond, rs1, rs2, target } => {
            format!("{} x{rs1}, x{rs2}, @{target}", cond_name(cond))
        }
        Inst::Jal { rd, target } => format!("jal x{rd}, @{target}"),
        Inst::Jalr { rd, rs1, offset } => format!("jalr x{rd}, {offset}(x{rs1})"),
        Inst::CsrR { rd, csr } => format!("csrr x{rd}, {}", csr_name(csr)),
        Inst::CsrW { csr, rs1 } => format!("csrw {}, x{rs1}", csr_name(csr)),
        Inst::Amo { op, rd, rs1, rs2 } => {
            let n = match op {
                AmoOp::Swap => "amoswap.w",
                AmoOp::Add => "amoadd.w",
                AmoOp::And => "amoand.w",
                AmoOp::Or => "amoor.w",
                AmoOp::Max => "amomax.w",
                AmoOp::Min => "amomin.w",
            };
            format!("{n} x{rd}, x{rs2}, (x{rs1})")
        }
        Inst::Flw { fd, rs1, offset } => format!("flw f{fd}, {offset}(x{rs1})"),
        Inst::Fsw { fs2, rs1, offset } => format!("fsw f{fs2}, {offset}(x{rs1})"),
        Inst::Fp { op, fd, fs1, fs2 } => format!("{} f{fd}, f{fs1}, f{fs2}", fp_name(op)),
        Inst::Fmadd { fd, fs1, fs2, fs3 } => {
            format!("fmadd.s f{fd}, f{fs1}, f{fs2}, f{fs3}")
        }
        Inst::FcvtSW { fd, rs1 } => format!("fcvt.s.w f{fd}, x{rs1}"),
        Inst::FcvtWS { rd, fs1 } => format!("fcvt.w.s x{rd}, f{fs1}"),
        Inst::FmvWX { fd, rs1 } => format!("fmv.w.x f{fd}, x{rs1}"),
        Inst::FmvXW { rd, fs1 } => format!("fmv.x.w x{rd}, f{fs1}"),
        Inst::Fcmp { cond, rd, fs1, fs2 } => {
            let n = match cond {
                Cond::Eq => "feq.s",
                Cond::Lt => "flt.s",
                _ => "fle.s",
            };
            format!("{n} x{rd}, f{fs1}, f{fs2}")
        }
        Inst::LwExt { rd, rs1, offset } => format!("lw.ext x{rd}, {offset}(x{rs1})"),
        Inst::SwExt { rs2, rs1, offset } => format!("sw.ext x{rs2}, {offset}(x{rs1})"),
        Inst::FlwExt { fd, rs1, offset } => format!("flw.ext f{fd}, {offset}(x{rs1})"),
        Inst::FswExt { fs2, rs1, offset } => format!("fsw.ext f{fs2}, {offset}(x{rs1})"),
        Inst::LwPost { rd, rs1, imm } => format!("p.lw x{rd}, {imm}(x{rs1}!)"),
        Inst::SwPost { rs2, rs1, imm } => format!("p.sw x{rs2}, {imm}(x{rs1}!)"),
        Inst::FlwPost { fd, rs1, imm } => format!("p.flw f{fd}, {imm}(x{rs1}!)"),
        Inst::FswPost { fs2, rs1, imm } => format!("p.fsw f{fs2}, {imm}(x{rs1}!)"),
        Inst::Mac { rd, rs1, rs2 } => format!("p.mac x{rd}, x{rs1}, x{rs2}"),
        Inst::Fmac { fd, fs1, fs2 } => format!("fmac.s f{fd}, f{fs1}, f{fs2}"),
        Inst::HwLoop { l, count, start, end } => {
            format!("lp.setup l{l}, x{count}, @{start}, @{end}")
        }
        Inst::DmaStart1D { rd, dir, dev, host_lo, host_hi, bytes } => {
            let d = if dir == DmaDir::HostToDev { "h2d" } else { "d2h" };
            format!("dma.1d.{d} x{rd}, dev=x{dev}, host=x{host_lo}:x{host_hi}, n=x{bytes}")
        }
        Inst::DmaStart2D { rd, dir, dev, host_lo, host_hi, bytes, count, dev_stride, host_stride } => {
            let d = if dir == DmaDir::HostToDev { "h2d" } else { "d2h" };
            format!(
                "dma.2d.{d} x{rd}, dev=x{dev}, host=x{host_lo}:x{host_hi}, n=x{bytes}, \
                 cnt=x{count}, dstr=x{dev_stride}, hstr=x{host_stride}"
            )
        }
        Inst::DmaWait { rs1 } => format!("dma.wait x{rs1}"),
        Inst::Barrier => "barrier".into(),
        Inst::Fork { target } => format!("fork @{target}"),
        Inst::Join => "join".into(),
        Inst::PerfCtl { resume } => {
            if resume { "perf.continue".into() } else { "perf.pause".into() }
        }
        Inst::Halt => "halt".into(),
        Inst::Nop => "nop".into(),
    }
}

/// Render a whole program with labels and indices.
pub fn program(p: &Program) -> String {
    let mut out = String::new();
    for (idx, i) in p.insts.iter().enumerate() {
        for (at, name) in &p.labels {
            if *at == idx as u32 {
                let _ = writeln!(out, "{name}:");
            }
        }
        let _ = writeln!(out, "  {idx:4}: {}", inst(i));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_xpulp_mnemonics() {
        assert_eq!(inst(&Inst::Mac { rd: 3, rs1: 1, rs2: 2 }), "p.mac x3, x1, x2");
        assert_eq!(inst(&Inst::FlwPost { fd: 1, rs1: 5, imm: 4 }), "p.flw f1, 4(x5!)");
        assert_eq!(
            inst(&Inst::HwLoop { l: 0, count: 7, start: 3, end: 8 }),
            "lp.setup l0, x7, @3, @8"
        );
    }

    #[test]
    fn renders_program_with_labels() {
        let mut p = Program::new(vec![Inst::Nop, Inst::Halt]);
        p.labels.push((1, "done".into()));
        let s = program(&p);
        assert!(s.contains("done:"));
        assert!(s.contains("0: nop"));
    }
}
