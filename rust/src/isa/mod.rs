//! The accelerator instruction set.
//!
//! HEROv2's accelerator cores are 32-bit RISC-V cores (CV32E40P on Aurora)
//! supporting at least RV32IMA, optionally F, and the Xpulpv2 custom
//! extension (§2.1): *hardware loops* (repeat an instruction sequence without
//! branches), *post-increment* loads/stores (implicitly bump the address
//! register), and *multiply-accumulate*.
//!
//! We model this as an RV32-flavoured virtual machine: instruction semantics
//! and cost structure match the paper's cores (single-issue, in-order,
//! 1 instruction/cycle unless stalled) but instructions are kept in decoded
//! enum form rather than 32-bit encodings — the case studies measure cycle
//! and instruction counts, which survive this abstraction (DESIGN.md §6).
//!
//! Submodules:
//! * [`disasm`] — assembly-style pretty printer (used in Fig 9 analysis).
//! * [`encoding`] — size/encoding model (compressed-instruction estimate for
//!   the L0 buffer and icache geometry).

pub mod disasm;
pub mod encoding;

/// Integer register index (x0..x31; x0 is hardwired zero).
pub type Reg = u8;
/// Floating-point register index (f0..f31).
pub type FReg = u8;

/// Integer ALU binary operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AluOp {
    Add,
    Sub,
    Mul,
    Mulhu,
    Div,
    Rem,
    And,
    Or,
    Xor,
    Sll,
    Srl,
    Sra,
    Slt,
    Sltu,
    /// Xpulpv2 `p.min` / `p.max` (bit-manipulation family, §2.1).
    Min,
    Max,
}

/// Floating-point binary operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FpOp {
    Add,
    Sub,
    Mul,
    Div,
    Min,
    Max,
}

/// Branch comparison conditions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cond {
    Eq,
    Ne,
    Lt,
    Ge,
    Ltu,
    Geu,
}

/// Atomic memory operations (RV32A subset used by the runtime).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AmoOp {
    Swap,
    Add,
    And,
    Or,
    Max,
    Min,
}

/// Control and status registers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Csr {
    /// Hart (core) id within the cluster.
    MHartId,
    /// Cluster id within the accelerator.
    MClusterId,
    /// Number of cores in this cluster.
    MNumCores,
    /// Upper 32 bits for 64-bit host-address-space accesses (§2.1: "a custom
    /// CSR allows each 32-bit core to load from and store to any 64-bit
    /// address"). Set by the compiler's host-pointer legalizer (§2.2.1).
    ExtAddr,
    /// Monotonic cycle counter.
    MCycle,
}

/// DMA transfer direction, from the accelerator's perspective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DmaDir {
    /// Main memory → SPM (`hero_memcpy_host2dev`).
    HostToDev,
    /// SPM → main memory (`hero_memcpy_dev2host`).
    DevToHost,
}

/// One decoded instruction.
///
/// Branch/jump targets are absolute instruction indices into the enclosing
/// [`Program`]. Loads/stores address bytes; word accesses must be 4-aligned.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Inst {
    // ---- RV32I/M integer core ----
    /// rd = imm (LUI/ADDI fusion; materializes a full 32-bit constant).
    Li { rd: Reg, imm: i32 },
    /// rd = rs1 op imm.
    AluImm { op: AluOp, rd: Reg, rs1: Reg, imm: i32 },
    /// rd = rs1 op rs2.
    Alu { op: AluOp, rd: Reg, rs1: Reg, rs2: Reg },
    /// rd = M[rs1 + offset] (32-bit, native address space).
    Lw { rd: Reg, rs1: Reg, offset: i32 },
    /// M[rs1 + offset] = rs2.
    Sw { rs2: Reg, rs1: Reg, offset: i32 },
    /// Conditional branch to `target` (absolute instruction index).
    Branch { cond: Cond, rs1: Reg, rs2: Reg, target: u32 },
    /// rd = return address; jump to `target`.
    Jal { rd: Reg, target: u32 },
    /// Indirect jump: pc = rs1 (+offset), rd = return address.
    Jalr { rd: Reg, rs1: Reg, offset: i32 },
    /// CSR read: rd = csr.
    CsrR { rd: Reg, csr: Csr },
    /// CSR write: csr = rs1.
    CsrW { csr: Csr, rs1: Reg },
    /// Atomic: rd = M[rs1]; M[rs1] = rd op rs2 (TCDM/L2 only).
    Amo { op: AmoOp, rd: Reg, rs1: Reg, rs2: Reg },

    // ---- RV32F ----
    /// fd = M[rs1 + offset].
    Flw { fd: FReg, rs1: Reg, offset: i32 },
    /// M[rs1 + offset] = fs2.
    Fsw { fs2: FReg, rs1: Reg, offset: i32 },
    /// fd = fs1 op fs2.
    Fp { op: FpOp, fd: FReg, fs1: FReg, fs2: FReg },
    /// fd = fs1 * fs2 + fs3 (RV32F FMADD.S).
    Fmadd { fd: FReg, fs1: FReg, fs2: FReg, fs3: FReg },
    /// fd = (float) rs1 (signed).
    FcvtSW { fd: FReg, rs1: Reg },
    /// rd = (int) fs1 (truncating).
    FcvtWS { rd: Reg, fs1: FReg },
    /// Move bit pattern: fd = rs1.
    FmvWX { fd: FReg, rs1: Reg },
    /// Move bit pattern: rd = fs1.
    FmvXW { rd: Reg, fs1: FReg },
    /// Float compare: rd = (fs1 cond fs2) ? 1 : 0 (Eq/Lt/Ge only).
    Fcmp { cond: Cond, rd: Reg, fs1: FReg, fs2: FReg },

    // ---- 64-bit host address space (ext-CSR path, §2.2.1) ----
    /// rd = M64[(ExtAddr << 32) | (rs1 + offset)] — remote load through the
    /// IOMMU. Costs `ext_addr_overhead` extra cycles (§2.3: 3 on TLB hit).
    LwExt { rd: Reg, rs1: Reg, offset: i32 },
    /// Remote store.
    SwExt { rs2: Reg, rs1: Reg, offset: i32 },
    /// Remote float load.
    FlwExt { fd: FReg, rs1: Reg, offset: i32 },
    /// Remote float store.
    FswExt { fs2: FReg, rs1: Reg, offset: i32 },

    // ---- Xpulpv2 ----
    /// Post-increment load: rd = M[rs1]; rs1 += imm (`p.lw rd, imm(rs1!)`).
    LwPost { rd: Reg, rs1: Reg, imm: i32 },
    /// Post-increment store: M[rs1] = rs2; rs1 += imm.
    SwPost { rs2: Reg, rs1: Reg, imm: i32 },
    /// Post-increment float load.
    FlwPost { fd: FReg, rs1: Reg, imm: i32 },
    /// Post-increment float store.
    FswPost { fs2: FReg, rs1: Reg, imm: i32 },
    /// Integer MAC: rd += rs1 * rs2 (`p.mac`).
    Mac { rd: Reg, rs1: Reg, rs2: Reg },
    /// Float MAC: fd += fs1 * fs2 (single-cycle on the FPnew MAC path).
    Fmac { fd: FReg, fs1: FReg, fs2: FReg },
    /// Hardware loop setup (`lp.setup l, rs1, start, end`): execute
    /// instructions `[start, end)` `rs1` times with zero-overhead back-edges.
    /// Two nested loops (l ∈ {0, 1}) are supported, as on CV32E40P.
    HwLoop { l: u8, count: Reg, start: u32, end: u32 },

    // ---- Runtime assists (HAL primitives, §2.3) ----
    /// Program a DMA 1D transfer: regs = [dev_addr, host_lo, host_hi,
    /// bytes]; rd = transfer id. Costs `dma.setup_cycles`.
    DmaStart1D { rd: Reg, dir: DmaDir, dev: Reg, host_lo: Reg, host_hi: Reg, bytes: Reg },
    /// Program a DMA 2D transfer: additionally [count, dev_stride,
    /// host_stride]; copies `count` rows of `bytes` each.
    DmaStart2D {
        rd: Reg,
        dir: DmaDir,
        dev: Reg,
        host_lo: Reg,
        host_hi: Reg,
        bytes: Reg,
        count: Reg,
        dev_stride: Reg,
        host_stride: Reg,
    },
    /// Block until transfer id in rs1 completes (`hero_memcpy_wait`).
    DmaWait { rs1: Reg },
    /// Cluster barrier (event unit).
    Barrier,
    /// Master wakes all cluster cores; they start at `target`. Workers run
    /// until they hit `Join`; the master continues at the next instruction
    /// *after* also executing the region (OpenMP `parallel` fork).
    Fork { target: u32 },
    /// End of a parallel region: implicit barrier; non-master cores go back
    /// to sleep, master falls through.
    Join,
    /// Pause/resume all allocated performance counters
    /// (`hero_perf_pause_all` / `hero_perf_continue_all`; 1 cycle, §2.4).
    PerfCtl { resume: bool },
    /// Stop this core; an offload finishes when core 0 halts (non-parallel
    /// sections run on core 0 only).
    Halt,
    /// No operation.
    Nop,
}

impl Inst {
    /// True for instructions that access data memory.
    pub fn is_mem(&self) -> bool {
        matches!(
            self,
            Inst::Lw { .. }
                | Inst::Sw { .. }
                | Inst::Flw { .. }
                | Inst::Fsw { .. }
                | Inst::LwExt { .. }
                | Inst::SwExt { .. }
                | Inst::FlwExt { .. }
                | Inst::FswExt { .. }
                | Inst::LwPost { .. }
                | Inst::SwPost { .. }
                | Inst::FlwPost { .. }
                | Inst::FswPost { .. }
                | Inst::Amo { .. }
        )
    }

    /// True for remote (64-bit host address space) accesses.
    pub fn is_remote(&self) -> bool {
        matches!(
            self,
            Inst::LwExt { .. } | Inst::SwExt { .. } | Inst::FlwExt { .. } | Inst::FswExt { .. }
        )
    }

    /// True for Xpulpv2-only instructions.
    pub fn is_xpulp(&self) -> bool {
        matches!(
            self,
            Inst::LwPost { .. }
                | Inst::SwPost { .. }
                | Inst::FlwPost { .. }
                | Inst::FswPost { .. }
                | Inst::Mac { .. }
                | Inst::Fmac { .. }
                | Inst::HwLoop { .. }
                | Inst::Alu { op: AluOp::Min | AluOp::Max, .. }
        )
    }
}

/// A device program: the decoded text segment of the device ELF that the
/// offload runtime loads into accelerator instruction memory.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub insts: Vec<Inst>,
    /// Entry point (instruction index).
    pub entry: u32,
    /// Optional label map for diagnostics (index → name).
    pub labels: Vec<(u32, String)>,
}

impl Program {
    pub fn new(insts: Vec<Inst>) -> Self {
        Program { insts, entry: 0, labels: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.insts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insts.is_empty()
    }

    /// Validate static well-formedness: branch/jump/hwloop targets in range,
    /// hwloop bodies non-empty and properly nested, x0 never written.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.insts.len() as u32;
        let check = |t: u32, what: &str, i: usize| {
            if t >= n {
                Err(format!("inst {i}: {what} target {t} out of range (len {n})"))
            } else {
                Ok(())
            }
        };
        for (i, inst) in self.insts.iter().enumerate() {
            match inst {
                Inst::Branch { target, .. } | Inst::Jal { target, .. } | Inst::Fork { target } => {
                    check(*target, "branch", i)?
                }
                Inst::HwLoop { start, end, l, .. } => {
                    check(*start, "hwloop start", i)?;
                    if *end > n {
                        return Err(format!("inst {i}: hwloop end {end} out of range"));
                    }
                    if start >= end {
                        return Err(format!("inst {i}: empty hwloop body [{start},{end})"));
                    }
                    if *l > 1 {
                        return Err(format!("inst {i}: hwloop index {l} > 1"));
                    }
                }
                Inst::Li { rd, .. } | Inst::AluImm { rd, .. } | Inst::Alu { rd, .. }
                    if *rd == 0 =>
                {
                    return Err(format!("inst {i}: write to x0"));
                }
                _ => {}
            }
        }
        if self.entry >= n && n > 0 {
            return Err(format!("entry {} out of range", self.entry));
        }
        Ok(())
    }

    /// Count instructions matching a predicate (used by the Fig 9 analysis).
    pub fn count<F: Fn(&Inst) -> bool>(&self, f: F) -> usize {
        self.insts.iter().filter(|i| f(i)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_accepts_simple_program() {
        let p = Program::new(vec![
            Inst::Li { rd: 1, imm: 5 },
            Inst::AluImm { op: AluOp::Add, rd: 1, rs1: 1, imm: -1 },
            Inst::Branch { cond: Cond::Ne, rs1: 1, rs2: 0, target: 1 },
            Inst::Halt,
        ]);
        p.validate().unwrap();
    }

    #[test]
    fn validate_rejects_out_of_range_branch() {
        let p = Program::new(vec![Inst::Branch {
            cond: Cond::Eq,
            rs1: 0,
            rs2: 0,
            target: 10,
        }]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_rejects_empty_hwloop() {
        let p =
            Program::new(vec![Inst::HwLoop { l: 0, count: 1, start: 1, end: 1 }, Inst::Halt]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_rejects_x0_write() {
        let p = Program::new(vec![Inst::Li { rd: 0, imm: 1 }, Inst::Halt]);
        assert!(p.validate().is_err());
    }

    #[test]
    fn xpulp_classification() {
        assert!(Inst::Mac { rd: 1, rs1: 2, rs2: 3 }.is_xpulp());
        assert!(Inst::LwPost { rd: 1, rs1: 2, imm: 4 }.is_xpulp());
        assert!(!Inst::Lw { rd: 1, rs1: 2, offset: 0 }.is_xpulp());
        assert!(Inst::LwExt { rd: 1, rs1: 2, offset: 0 }.is_remote());
    }
}
