//! Instruction-size model.
//!
//! The cores support the RISC-V compressed (`C`) extension; the paper's L0
//! buffer holds "up to eight compressed instructions" (§2.1). The icache
//! geometry and the L0 capacity check therefore need a size estimate for
//! each instruction: common ALU ops, short-immediate loads/stores and
//! branches compress to 16 bit, everything else is 32 bit.

use super::*;

/// Estimated encoded size in bytes (2 for compressible, 4 otherwise).
pub fn size_bytes(i: &Inst) -> u32 {
    match i {
        // RVC-compressible forms: small immediates / register-register moves.
        Inst::AluImm { imm, .. } if (-32..32).contains(imm) => 2,
        Inst::Alu { .. } => 2,
        Inst::Lw { offset, .. } | Inst::Sw { offset, .. } if (0..128).contains(offset) => 2,
        Inst::Flw { offset, .. } | Inst::Fsw { offset, .. } if (0..128).contains(offset) => 2,
        Inst::Li { imm, .. } if (-32..32).contains(imm) => 2,
        Inst::Nop | Inst::Halt | Inst::Join => 2,
        // Everything else (incl. all Xpulpv2 and ext-address forms) is 32-bit.
        _ => 4,
    }
}

/// Total encoded size of an instruction range in bytes.
pub fn range_bytes(insts: &[Inst]) -> u32 {
    insts.iter().map(size_bytes).sum()
}

/// Whether an instruction window fits the per-core L0 buffer of
/// `l0_insts` compressed (16-bit) slots, i.e. `2 * l0_insts` bytes.
pub fn fits_l0(insts: &[Inst], l0_insts: usize) -> bool {
    range_bytes(insts) <= 2 * l0_insts as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(size_bytes(&Inst::Alu { op: AluOp::Add, rd: 1, rs1: 1, rs2: 2 }), 2);
        assert_eq!(size_bytes(&Inst::Li { rd: 1, imm: 100000 }), 4);
        assert_eq!(size_bytes(&Inst::Mac { rd: 1, rs1: 2, rs2: 3 }), 4);
        assert_eq!(size_bytes(&Inst::Lw { rd: 1, rs1: 2, offset: 4 }), 2);
        assert_eq!(size_bytes(&Inst::Lw { rd: 1, rs1: 2, offset: 1024 }), 4);
    }

    #[test]
    fn l0_capacity() {
        // Eight compressed instructions fit; eight uncompressed do not.
        let small = vec![Inst::Alu { op: AluOp::Add, rd: 1, rs1: 1, rs2: 2 }; 8];
        assert!(fits_l0(&small, 8));
        let big = vec![Inst::Mac { rd: 1, rs1: 2, rs2: 3 }; 8];
        assert!(!fits_l0(&big, 8));
        assert!(fits_l0(&big, 16));
    }
}
