//! Interpreter unit tests: ISA semantics, timing behaviours, fork/join,
//! DMA data movement, remote accesses.

use super::*;
use crate::config::aurora;
use crate::isa::Inst as I;
use crate::mem::map::TCDM_BASE;

const HOST_BASE: u64 = 0x40_0000_0000;

fn accel() -> Accel {
    let mut a = Accel::new(aurora(), 1 << 20);
    // Identity-ish mapping: host VA window onto DRAM PA 0..1 MiB.
    a.pt.map_range(HOST_BASE, 0, 1 << 20);
    a
}

fn run(a: &mut Accel, insts: Vec<I>, args: &[u32]) -> u64 {
    a.load_program(Arc::new(Program::new(insts)), 1).unwrap();
    a.set_args(args, &[]).unwrap();
    a.run(1_000_000).unwrap()
}

fn reg(a: &Accel, r: u8) -> u32 {
    a.clusters[0].cores[0].regs[r as usize]
}

#[test]
fn arithmetic_loop_counts_down() {
    let mut a = accel();
    // x1 = 10; loop { x2 += x1; x1 -= 1 } while x1 != 0
    run(
        &mut a,
        vec![
            I::Li { rd: 1, imm: 10 },
            I::Alu { op: AluOp::Add, rd: 2, rs1: 2, rs2: 1 },
            I::AluImm { op: AluOp::Add, rd: 1, rs1: 1, imm: -1 },
            I::Branch { cond: Cond::Ne, rs1: 1, rs2: 0, target: 1 },
            I::Halt,
        ],
        &[],
    );
    assert_eq!(reg(&a, 2), 55);
}

#[test]
fn tcdm_load_store_roundtrip() {
    let mut a = accel();
    run(
        &mut a,
        vec![
            I::Li { rd: 1, imm: TCDM_BASE as i32 },
            I::Li { rd: 2, imm: 1234 },
            I::Sw { rs2: 2, rs1: 1, offset: 8 },
            I::Lw { rd: 3, rs1: 1, offset: 8 },
            I::Halt,
        ],
        &[],
    );
    assert_eq!(reg(&a, 3), 1234);
    assert_eq!(a.clusters[0].tcdm.mem.load(8), 1234);
}

#[test]
fn float_mac_path() {
    let mut a = accel();
    // f1 = 2.0, f2 = 3.0, f3 = 10.0; f3 += f1*f2 -> 16.0
    let p = vec![
        I::Li { rd: 1, imm: 2.0f32.to_bits() as i32 },
        I::FmvWX { fd: 1, rs1: 1 },
        I::Li { rd: 2, imm: 3.0f32.to_bits() as i32 },
        I::FmvWX { fd: 2, rs1: 2 },
        I::Li { rd: 3, imm: 10.0f32.to_bits() as i32 },
        I::FmvWX { fd: 3, rs1: 3 },
        I::Fmac { fd: 3, fs1: 1, fs2: 2 },
        I::Halt,
    ];
    run(&mut a, p, &[]);
    assert_eq!(a.clusters[0].cores[0].fregs[3], 16.0);
}

#[test]
fn hwloop_executes_n_times_with_zero_overhead() {
    let mut a = accel();
    // lp.setup l0, x1(=100), body = [2,4): x2 += 1; x3 += 2
    let cycles = run(
        &mut a,
        vec![
            I::Li { rd: 1, imm: 100 },
            I::HwLoop { l: 0, count: 1, start: 2, end: 4 },
            I::AluImm { op: AluOp::Add, rd: 2, rs1: 2, imm: 1 },
            I::AluImm { op: AluOp::Add, rd: 3, rs1: 3, imm: 2 },
            I::Halt,
        ],
        &[],
    );
    assert_eq!(reg(&a, 2), 100);
    assert_eq!(reg(&a, 3), 200);
    // 2 setup insts + 200 body executions + halt + icache compulsory misses;
    // zero loop overhead means cycles ≈ 203 + fetch.
    assert!(cycles < 230, "hwloop not zero-overhead: {cycles} cycles");
}

#[test]
fn hwloop_zero_count_skips_body() {
    let mut a = accel();
    run(
        &mut a,
        vec![
            I::HwLoop { l: 0, count: 1, start: 1, end: 3 }, // x1 = 0
            I::AluImm { op: AluOp::Add, rd: 2, rs1: 2, imm: 1 },
            I::AluImm { op: AluOp::Add, rd: 2, rs1: 2, imm: 1 },
            I::Halt,
        ],
        &[],
    );
    assert_eq!(reg(&a, 2), 0);
}

#[test]
fn nested_hwloops() {
    let mut a = accel();
    // outer(l1) 5 times { inner(l0) 4 times { x3 += 1 } }
    run(
        &mut a,
        vec![
            I::Li { rd: 1, imm: 5 },
            I::Li { rd: 2, imm: 4 },
            I::HwLoop { l: 1, count: 1, start: 3, end: 5 },
            I::HwLoop { l: 0, count: 2, start: 4, end: 5 },
            I::AluImm { op: AluOp::Add, rd: 3, rs1: 3, imm: 1 },
            I::Halt,
        ],
        &[],
    );
    assert_eq!(reg(&a, 3), 20);
}

#[test]
fn branch_costs_more_than_hwloop() {
    // The same 100-iteration loop with a branch back-edge must be slower
    // than with a hardware loop (Fig 9 mechanism).
    let mut a1 = accel();
    let c_branch = run(
        &mut a1,
        vec![
            I::Li { rd: 1, imm: 100 },
            I::AluImm { op: AluOp::Add, rd: 2, rs1: 2, imm: 1 },
            I::AluImm { op: AluOp::Add, rd: 1, rs1: 1, imm: -1 },
            I::Branch { cond: Cond::Ne, rs1: 1, rs2: 0, target: 1 },
            I::Halt,
        ],
        &[],
    );
    let mut a2 = accel();
    let c_hw = run(
        &mut a2,
        vec![
            I::Li { rd: 1, imm: 100 },
            I::HwLoop { l: 0, count: 1, start: 2, end: 4 },
            I::AluImm { op: AluOp::Add, rd: 2, rs1: 2, imm: 1 },
            I::Nop,
            I::Halt,
        ],
        &[],
    );
    assert!(c_branch > c_hw + 80, "branch {c_branch} vs hwloop {c_hw}");
}

#[test]
fn remote_load_sees_host_data_and_pays_latency() {
    let mut a = accel();
    a.dram.mem.store(0x100, 77);
    let hi = (HOST_BASE >> 32) as i32;
    let lo = (HOST_BASE & 0xffff_ffff) as i32 + 0x100;
    let cycles = run(
        &mut a,
        vec![
            I::Li { rd: 1, imm: hi },
            I::CsrW { csr: Csr::ExtAddr, rs1: 1 },
            I::Li { rd: 2, imm: lo },
            I::LwExt { rd: 3, rs1: 2, offset: 0 },
            I::Halt,
        ],
        &[],
    );
    assert_eq!(reg(&a, 3), 77);
    let t = aurora().timing;
    // First access: TLB miss -> walk; plus remote latency + ext overhead.
    assert!(
        cycles >= aurora().iommu.walk_cycles + t.remote_word + t.ext_addr_overhead,
        "remote load too cheap: {cycles}"
    );
    let perf = a.clusters[0].cores[0].perf.clone();
    assert_eq!(perf.get(Event::TlbMiss), 1);
    assert_eq!(perf.get(Event::RemoteAccess), 1);
}

#[test]
fn second_remote_access_hits_tlb() {
    let mut a = accel();
    a.dram.mem.store(0x104, 5);
    let hi = (HOST_BASE >> 32) as i32;
    let lo = (HOST_BASE & 0xffff_ffff) as i32;
    run(
        &mut a,
        vec![
            I::Li { rd: 1, imm: hi },
            I::CsrW { csr: Csr::ExtAddr, rs1: 1 },
            I::Li { rd: 2, imm: lo },
            I::LwExt { rd: 3, rs1: 2, offset: 0x100 },
            I::LwExt { rd: 4, rs1: 2, offset: 0x104 },
            I::Halt,
        ],
        &[],
    );
    let perf = a.clusters[0].cores[0].perf.clone();
    assert_eq!(perf.get(Event::TlbMiss), 1);
    assert_eq!(perf.get(Event::TlbHit), 1);
    assert_eq!(reg(&a, 4), 5);
}

#[test]
fn remote_store_is_posted() {
    let mut a = accel();
    let hi = (HOST_BASE >> 32) as i32;
    let lo = (HOST_BASE & 0xffff_ffff) as i32;
    // Prime the TLB with a load, then measure store cost: it must be far
    // cheaper than a load (posted write).
    run(
        &mut a,
        vec![
            I::Li { rd: 1, imm: hi },
            I::CsrW { csr: Csr::ExtAddr, rs1: 1 },
            I::Li { rd: 2, imm: lo },
            I::LwExt { rd: 3, rs1: 2, offset: 0 },
            I::Li { rd: 4, imm: 99 },
            I::SwExt { rs2: 4, rs1: 2, offset: 8 },
            I::Halt,
        ],
        &[],
    );
    assert_eq!(a.dram.mem.load(8), 99);
}

#[test]
fn dma_1d_roundtrip_moves_data() {
    let mut a = accel();
    for i in 0..64u32 {
        a.dram.mem.store(i * 4, i + 1000);
    }
    let hi = (HOST_BASE >> 32) as u32;
    let lo = HOST_BASE as u32;
    // args: x10 = dev, x11 = host_lo, x12 = host_hi, x13 = bytes
    run(
        &mut a,
        vec![
            I::DmaStart1D { rd: 5, dir: DmaDir::HostToDev, dev: 10, host_lo: 11, host_hi: 12, bytes: 13 },
            I::DmaWait { rs1: 5 },
            I::Halt,
        ],
        &[TCDM_BASE, lo, hi, 256],
    );
    for i in 0..64u32 {
        assert_eq!(a.clusters[0].tcdm.mem.load(i * 4), i + 1000);
    }
    let perf = a.clusters[0].cores[0].perf.clone();
    assert_eq!(perf.get(Event::DmaBytes), 256);
    assert_eq!(perf.get(Event::DmaTransfers), 1);
    assert!(perf.get(Event::DmaWaitCycles) > 0, "core must block on dma.wait");
}

#[test]
fn dma_2d_gathers_rows() {
    let mut a = accel();
    // Host matrix: 8 rows x 16 words, gather a 4x4 tile at (2,3).
    for r in 0..8u32 {
        for c in 0..16u32 {
            a.dram.mem.store((r * 16 + c) * 4, r * 100 + c);
        }
    }
    let tile_va = HOST_BASE + ((2 * 16 + 3) * 4) as u64;
    run(
        &mut a,
        vec![
            I::DmaStart2D {
                rd: 5,
                dir: DmaDir::HostToDev,
                dev: 10,
                host_lo: 11,
                host_hi: 12,
                bytes: 13,
                count: 14,
                dev_stride: 15,
                host_stride: 16,
            },
            I::DmaWait { rs1: 5 },
            I::Halt,
        ],
        &[
            TCDM_BASE,
            tile_va as u32,
            (tile_va >> 32) as u32,
            16, // 4 words per row
            4,  // 4 rows
            16, // dense dev stride
            64, // host stride = full row of 16 words
        ],
    );
    for r in 0..4u32 {
        for c in 0..4u32 {
            let got = a.clusters[0].tcdm.mem.load((r * 4 + c) * 4);
            assert_eq!(got, (r + 2) * 100 + (c + 3), "tile ({r},{c})");
        }
    }
    // 2D transfer = one burst per row.
    assert_eq!(a.clusters[0].cores[0].perf.get(Event::DmaBursts), 4);
}

#[test]
fn fork_join_parallel_sum() {
    let mut a = accel();
    // Master: x1 = TCDM base. Fork: every core writes its hartid to
    // TCDM[hartid], then Join; master sums afterwards.
    let base = TCDM_BASE as i32;
    run(
        &mut a,
        vec![
            // 0: entry
            I::Fork { target: 1 },
            // 1: parallel region (all 8 cores)
            I::CsrR { rd: 2, csr: Csr::MHartId },
            I::Li { rd: 1, imm: base },
            I::AluImm { op: AluOp::Sll, rd: 3, rs1: 2, imm: 2 },
            I::Alu { op: AluOp::Add, rd: 3, rs1: 1, rs2: 3 },
            I::Sw { rs2: 2, rs1: 3, offset: 0 },
            I::Join,
            // 7: master-only continuation: sum TCDM[0..8]
            I::Li { rd: 1, imm: base },
            I::Li { rd: 4, imm: 8 },
            I::Li { rd: 5, imm: 0 },
            I::LwPost { rd: 6, rs1: 1, imm: 4 },
            I::Alu { op: AluOp::Add, rd: 5, rs1: 5, rs2: 6 },
            I::AluImm { op: AluOp::Add, rd: 4, rs1: 4, imm: -1 },
            I::Branch { cond: Cond::Ne, rs1: 4, rs2: 0, target: 10 },
            I::Halt,
        ],
        &[],
    );
    assert_eq!(reg(&a, 5), 0 + 1 + 2 + 3 + 4 + 5 + 6 + 7);
    // Workers must be asleep again after Join.
    for c in 1..8 {
        assert_eq!(a.clusters[0].cores[c].state, CoreState::Sleeping, "core {c}");
    }
}

#[test]
fn parallel_speedup_is_near_linear_for_independent_work() {
    // 8 cores each spinning on independent ALU work must be ~8x faster than one core
    // doing all of it serially.
    let work_per_core = 2_000;
    let mut a1 = accel();
    let serial = run(
        &mut a1,
        vec![
            I::Li { rd: 1, imm: 8 * work_per_core },
            I::AluImm { op: AluOp::Add, rd: 1, rs1: 1, imm: -1 },
            I::Branch { cond: Cond::Ne, rs1: 1, rs2: 0, target: 1 },
            I::Halt,
        ],
        &[],
    );
    let mut a8 = accel();
    let parallel = run(
        &mut a8,
        vec![
            I::Fork { target: 1 },
            I::Li { rd: 1, imm: work_per_core },
            I::AluImm { op: AluOp::Add, rd: 1, rs1: 1, imm: -1 },
            I::Branch { cond: Cond::Ne, rs1: 1, rs2: 0, target: 2 },
            I::Join,
            I::Halt,
        ],
        &[],
    );
    let speedup = serial as f64 / parallel as f64;
    assert!((6.5..8.5).contains(&speedup), "speedup {speedup} (serial {serial}, par {parallel})");
}

#[test]
fn tcdm_bank_conflicts_are_counted() {
    let mut a = accel();
    // All 8 cores hammer the SAME TCDM word -> same bank every cycle.
    run(
        &mut a,
        vec![
            I::Fork { target: 1 },
            I::Li { rd: 1, imm: TCDM_BASE as i32 },
            I::Li { rd: 2, imm: 500 },
            I::Lw { rd: 3, rs1: 1, offset: 0 },
            I::AluImm { op: AluOp::Add, rd: 2, rs1: 2, imm: -1 },
            I::Branch { cond: Cond::Ne, rs1: 2, rs2: 0, target: 3 },
            I::Join,
            I::Halt,
        ],
        &[],
    );
    let agg = a.perf_aggregate();
    assert!(
        agg.get(Event::TcdmConflict) > 1000,
        "expected heavy conflicts, got {}",
        agg.get(Event::TcdmConflict)
    );
}

#[test]
fn amo_add_is_atomic_across_cores() {
    let mut a = accel();
    // Each core does 100 amoadd(+1) on the same counter.
    run(
        &mut a,
        vec![
            I::Fork { target: 1 },
            I::Li { rd: 1, imm: TCDM_BASE as i32 },
            I::Li { rd: 2, imm: 100 },
            I::Li { rd: 3, imm: 1 },
            I::Amo { op: AmoOp::Add, rd: 4, rs1: 1, rs2: 3 },
            I::AluImm { op: AluOp::Add, rd: 2, rs1: 2, imm: -1 },
            I::Branch { cond: Cond::Ne, rs1: 2, rs2: 0, target: 4 },
            I::Join,
            I::Halt,
        ],
        &[],
    );
    assert_eq!(a.clusters[0].tcdm.mem.load(0), 800);
}

#[test]
fn perf_pause_stops_cycle_attribution() {
    let mut a = accel();
    run(
        &mut a,
        vec![
            I::PerfCtl { resume: false },
            I::Li { rd: 1, imm: 1000 },
            I::AluImm { op: AluOp::Add, rd: 1, rs1: 1, imm: -1 },
            I::Branch { cond: Cond::Ne, rs1: 1, rs2: 0, target: 2 },
            I::PerfCtl { resume: true },
            I::Halt,
        ],
        &[],
    );
    let perf = a.clusters[0].cores[0].perf.clone();
    // Only the instructions after resume are counted.
    assert!(perf.get(Event::Instructions) <= 2, "{}", perf.get(Event::Instructions));
}

#[test]
fn offload_timeout_errors() {
    let mut a = accel();
    a.load_program(
        Arc::new(Program::new(vec![I::Jal { rd: 0, target: 0 }])),
        1,
    )
    .unwrap();
    assert!(a.run(1_000).is_err());
}

#[test]
fn args_reach_core0() {
    let mut a = accel();
    run(&mut a, vec![I::Alu { op: AluOp::Add, rd: 1, rs1: 10, rs2: 11 }, I::Halt], &[30, 12]);
    assert_eq!(reg(&a, 1), 42);
}
