//! The accelerator (PMCA) model: clusters + L2 SPM + IOMMU + DRAM port,
//! and the cycle-stepped instruction interpreter.
//!
//! Execution model (§2.1): single-issue in-order cores, 1 instruction per
//! cycle unless stalled by TCDM bank conflicts, icache refills, remote
//! accesses, DMA programming/waiting, or barriers. The interpreter is
//! instruction-accurate (it computes the real data values — the simulated
//! kernel's numerics are later checked against the PJRT-executed HLO
//! artifact) and cycle-approximate with the cost model of DESIGN.md §5.

use crate::cluster::{Cluster, CoreState, HwLoopState};
use crate::config::HeroConfig;
use crate::dma::Descriptor;
use crate::iommu::{Iommu, PageTable};
use crate::isa::{AluOp, AmoOp, Cond, Csr, DmaDir, FpOp, Inst, Program};
use crate::mem::{map, DramPort, SharedDram, WordMem};
use crate::trace::Event;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Fixed-size fetch group refilled into the prefetch buffer on a taken
/// control transfer that misses the L0 window (bytes).
const FETCH_GROUP_BYTES: u64 = 8;

/// Wake-up latency of a sleeping core on `Fork` (event-unit trigger).
const FORK_WAKE_CYCLES: u64 = 5;

/// Marker prefix of the error raised when an offload exhausts its
/// simulation budget ([`Accel::run`]'s `max_cycles`). The scheduler's
/// watchdog ([`crate::sched::Scheduler::with_watchdog`]) keys on this
/// exact string to turn a budget overrun into a deadline fault — change
/// both together.
pub const BUDGET_EXHAUSTED_MARKER: &str = "offload did not complete";

/// Whether an error (anywhere in its chain) is an offload-budget
/// exhaustion, as opposed to a genuine execution error.
pub fn is_budget_exhausted(e: &anyhow::Error) -> bool {
    e.chain().any(|c| c.to_string().contains(BUDGET_EXHAUSTED_MARKER))
}

/// The accelerator: everything on the device side of the mailbox.
pub struct Accel {
    pub cfg: HeroConfig,
    pub clusters: Vec<Cluster>,
    /// Shared L2 SPM.
    pub l2: WordMem,
    /// Shared carrier-board main memory: storage plus the cycle-accounted
    /// bandwidth model every cluster's DMA engine and the narrow
    /// ext-address path contend on (see [`crate::mem::dram`]).
    pub dram: SharedDram,
    /// This accelerator's requester port for narrow (single-word remote)
    /// main-memory accesses.
    narrow_dram_port: DramPort,
    /// Hybrid IOMMU shared by all clusters.
    pub iommu: Iommu,
    /// Host-managed application page table (read-only for the accelerator).
    pub pt: PageTable,
    /// Page-table epoch the TLB contents were filled against (see
    /// [`Accel::flush_tlb_if_stale`]).
    pt_epoch_seen: u64,
    /// Current cycle.
    pub now: u64,
    /// Clusters participating in the current offload.
    active_clusters: usize,
    /// Precomputed per-step constants (hot-loop; see EXPERIMENTS.md §Perf).
    kc: StepConsts,
}

/// Constants the interpreter needs on every step, hoisted out of the hot
/// loop (reading them from `HeroConfig` per step cost ~25 % throughput).
#[derive(Debug, Clone, Copy)]
struct StepConsts {
    l0_insts: u32,
    line_insts: u32,
    icache_refill: u64,
    ifetch: u64,
    fetch_pen: u64,
    branch_cost: u64,
    l1_bytes: u32,
}

impl Accel {
    /// Build an accelerator with `dram_bytes` of backing main memory (the
    /// configured capacity is typically 4 GiB; the simulator allocates only
    /// what experiments need).
    pub fn new(cfg: HeroConfig, dram_bytes: usize) -> Self {
        cfg.validate().map_err(|e| anyhow::anyhow!(e)).expect("invalid config");
        // One shared DRAM for the whole board: every cluster's DMA engine
        // gets its own requester port, plus one for the narrow path. With
        // the paper configurations the DRAM peak far exceeds the per-port
        // NoC rates, so contention only appears when a config (or the
        // instance pool) narrows the shared bandwidth.
        let mut dram = SharedDram::new(dram_bytes, cfg.dram.bytes_per_cycle, 0);
        let clusters = (0..cfg.accel.n_clusters)
            .map(|i| {
                let port = dram.add_port(format!("cluster{i}-dma"), false);
                Cluster::new(i, &cfg, port)
            })
            .collect();
        let narrow_dram_port = dram.add_port("narrow", false);
        let kc = StepConsts {
            l0_insts: cfg.accel.l0_insts as u32,
            line_insts: cfg.accel.icache_line_insts as u32,
            icache_refill: cfg.timing.icache_refill,
            ifetch: cfg.ifetch_bytes_per_cycle().max(1),
            fetch_pen: FETCH_GROUP_BYTES / cfg.ifetch_bytes_per_cycle().max(1),
            branch_cost: cfg.timing.branch_taken,
            l1_bytes: cfg.accel.l1_bytes as u32,
        };
        Accel {
            kc,
            l2: WordMem::new(cfg.accel.l2_bytes),
            dram,
            narrow_dram_port,
            iommu: Iommu::new(cfg.iommu),
            pt: PageTable::new(cfg.iommu.page_bytes),
            pt_epoch_seen: 0,
            clusters,
            cfg,
            now: 0,
            active_clusters: 0,
        }
    }

    /// Driver-side TLB maintenance at offload time: flush only when the
    /// page table changed since the TLB was last filled (or always, when
    /// `iommu.flush_on_offload` pins the old flush-every-offload behavior).
    /// Repeated offloads over an unchanged mapping keep a warm TLB — the
    /// precondition for the SVM pin-path studies.
    pub fn flush_tlb_if_stale(&mut self) {
        if self.cfg.iommu.flush_on_offload || self.pt.epoch() != self.pt_epoch_seen {
            self.iommu.flush();
            self.pt_epoch_seen = self.pt.epoch();
        }
    }

    /// Load `program` into the instruction memory of the first `n_clusters`
    /// clusters and reset their cores (the offload runtime's "load device
    /// ELF" step).
    pub fn load_program(&mut self, program: Arc<Program>, n_clusters: usize) -> Result<()> {
        program.validate().map_err(|e| anyhow::anyhow!("program invalid: {e}"))?;
        if n_clusters == 0 || n_clusters > self.clusters.len() {
            bail!("n_clusters {n_clusters} out of range 1..={}", self.clusters.len());
        }
        for cl in &mut self.clusters[..n_clusters] {
            cl.load_program(program.clone());
        }
        self.active_clusters = n_clusters;
        Ok(())
    }

    /// Pass kernel arguments to core 0 of every active cluster: integer
    /// arguments in x10.., float arguments in f10.. .
    pub fn set_args(&mut self, args: &[u32], fargs: &[f32]) -> Result<()> {
        if args.len() > 16 || fargs.len() > 8 {
            bail!("too many kernel arguments ({} int, {} float)", args.len(), fargs.len());
        }
        for cl in &mut self.clusters[..self.active_clusters] {
            let core0 = &mut cl.cores[0];
            for (i, a) in args.iter().enumerate() {
                core0.regs[10 + i] = *a;
            }
            for (i, f) in fargs.iter().enumerate() {
                core0.fregs[10 + i] = *f;
            }
        }
        Ok(())
    }

    /// True when the current offload has finished (core 0 of every active
    /// cluster halted).
    pub fn offload_done(&self) -> bool {
        self.clusters[..self.active_clusters]
            .iter()
            .all(|cl| cl.cores[0].state == CoreState::Halted)
    }

    /// Run until the offload completes or `max_cycles` elapse. Returns the
    /// number of cycles executed.
    ///
    /// Budget exhaustion bails with [`BUDGET_EXHAUSTED_MARKER`] so the
    /// scheduler's watchdog can tell it apart from genuine execution
    /// errors ([`is_budget_exhausted`]).
    pub fn run(&mut self, max_cycles: u64) -> Result<u64> {
        let start = self.now;
        while !self.offload_done() {
            if self.now - start >= max_cycles {
                bail!(
                    "{BUDGET_EXHAUSTED_MARKER} within {max_cycles} cycles \
                     (pc of cluster 0 core 0: {})",
                    self.clusters[0].cores[0].pc
                );
            }
            self.step_cycle();
        }
        Ok(self.now - start)
    }

    /// Advance the whole accelerator by one cycle.
    pub fn step_cycle(&mut self) {
        let now = self.now;
        let n_active = self.active_clusters;
        for cl_idx in 0..n_active {
            // Barrier release is evaluated at cycle start so that the last
            // arriving core's arrival cycle is the release reference.
            if self.clusters[cl_idx].barrier_waiters > 0 && self.clusters[cl_idx].barrier_ready()
            {
                let cost = self.cfg.timing.barrier;
                self.clusters[cl_idx].release_barrier(now, cost);
            }
            let n_cores = self.clusters[cl_idx].cores.len();
            // Rotating arbitration priority for fairness.
            let rot = (now as usize) % n_cores;
            for k in 0..n_cores {
                let c = (k + rot) % n_cores;
                self.step_core(cl_idx, c);
            }
            self.clusters[cl_idx].dma.retire(now.saturating_sub(1_000));
        }
        if now % 1024 == 0 {
            // Bound the DRAM ledger's breakpoint list on long runs.
            self.dram.trim(now.saturating_sub(4_096));
        }
        self.now += 1;
    }

    /// Aggregate perf counters across all clusters and cores.
    pub fn perf_aggregate(&self) -> crate::trace::PerfCounters {
        let mut agg = crate::trace::PerfCounters::new();
        for cl in &self.clusters {
            agg.merge(&cl.perf_aggregate());
        }
        agg
    }

    // --- interpreter -----------------------------------------------------

    /// Fast path: handles the common case — a running, unstalled core
    /// executing a cluster-local instruction — with a single split borrow
    /// of the cluster (no repeated deep indexing). Everything else falls
    /// back to [`Accel::step_core_slow`]. The fast path performs no state
    /// mutation before deciding it can complete, so the fallback re-executes
    /// from scratch safely.
    #[inline]
    fn step_core(&mut self, cl_idx: usize, c_idx: usize) {
        let now = self.now;
        let StepConsts {
            l0_insts,
            line_insts,
            icache_refill,
            ifetch,
            fetch_pen,
            branch_cost: branch_taken_cost,
            l1_bytes,
        } = self.kc;
        let tcdm_base = map::tcdm_base(cl_idx);
        {
            let cluster = &mut self.clusters[cl_idx];
            let Cluster {
                cores,
                tcdm,
                bank_claim,
                icache_tags,
                refill_port,
                program,
                extra_conflict_ppm,
                fast_mask,
                ..
            } = cluster;
            let n_cores = cores.len() as u32;
            let core = &mut cores[c_idx];
            match core.state {
                CoreState::Running => {}
                CoreState::Sleeping | CoreState::Halted | CoreState::WaitBarrier { .. } => {
                    return
                }
                CoreState::WaitDma { .. } => return self.step_core_slow(cl_idx, c_idx),
            }
            if core.stall_until > now {
                return;
            }
            let pc = core.pc;
            if !cluster_fast_mask_get(fast_mask, pc) {
                return self.step_core_slow(cl_idx, c_idx);
            }
            // --- fetch (full model, fast borrows) ---
            if !(core.l0_base..core.l0_base + l0_insts).contains(&pc) {
                let line = pc / line_insts;
                let slot = (line as usize) % icache_tags.len();
                if icache_tags[slot] != line {
                    let dur = icache_refill + (line_insts as u64 * 4) / ifetch;
                    let (_, end) = refill_port.acquire(now, dur);
                    icache_tags[slot] = line;
                    core.stall_until = end;
                    core.perf.bump(Event::IcacheMiss);
                    core.perf.add(Event::IFetchStall, end - now);
                    return;
                }
            } else {
                core.perf.bump(Event::L0Hit);
            }
            let inst = program.insts[pc as usize];
            // TCDM access helper: Some(offset) when the address is in this
            // cluster's TCDM and the bank is free this cycle; Err = conflict.
            macro_rules! tcdm_claim_fast {
                ($addr:expr) => {{
                    let off = $addr.wrapping_sub(tcdm_base);
                    if off >= l1_bytes {
                        None // not local: slow path
                    } else {
                        let bank = ((off / 4) as usize) % bank_claim.len();
                        let skew = *extra_conflict_ppm > 0 && {
                            let h = (now ^ (off as u64 ^ ((c_idx as u64) << 17)))
                                .wrapping_mul(0x9E37_79B9_7F4A_7C15);
                            (h >> 40) % 1_000_000 < *extra_conflict_ppm
                        };
                        if bank_claim[bank] == now || skew {
                            core.perf.bump(Event::TcdmConflict);
                            return; // retry next cycle
                        }
                        bank_claim[bank] = now;
                        core.perf.bump(Event::TcdmAccess);
                        Some(off)
                    }
                }};
            }
            let mut extra: u64 = 0;
            let mut branch_to: Option<u32> = None;
            match inst {
                Inst::Li { rd, imm } => core.set_reg(rd, imm as u32),
                Inst::AluImm { op, rd, rs1, imm } => {
                    let v = alu(op, core.reg(rs1), imm as u32);
                    core.set_reg(rd, v);
                }
                Inst::Alu { op, rd, rs1, rs2 } => {
                    let v = alu(op, core.reg(rs1), core.reg(rs2));
                    core.set_reg(rd, v);
                }
                Inst::Branch { cond, rs1, rs2, target } => {
                    if branch_taken(cond, core.reg(rs1), core.reg(rs2)) {
                        branch_to = Some(target);
                        extra += branch_taken_cost;
                        core.perf.bump(Event::BranchTaken);
                    }
                }
                Inst::Jal { rd, target } => {
                    core.set_reg(rd, pc + 1);
                    branch_to = Some(target);
                }
                Inst::Fp { op, fd, fs1, fs2 } => {
                    let (a, b) = (core.fregs[fs1 as usize], core.fregs[fs2 as usize]);
                    core.fregs[fd as usize] = match op {
                        FpOp::Add => a + b,
                        FpOp::Sub => a - b,
                        FpOp::Mul => a * b,
                        FpOp::Div => a / b,
                        FpOp::Min => a.min(b),
                        FpOp::Max => a.max(b),
                    };
                }
                Inst::Fmadd { fd, fs1, fs2, fs3 } => {
                    core.fregs[fd as usize] = core.fregs[fs1 as usize]
                        * core.fregs[fs2 as usize]
                        + core.fregs[fs3 as usize];
                }
                Inst::Fmac { fd, fs1, fs2 } => {
                    let v = core.fregs[fs1 as usize] * core.fregs[fs2 as usize];
                    core.fregs[fd as usize] += v;
                }
                Inst::Mac { rd, rs1, rs2 } => {
                    let v = core.reg(rs1).wrapping_mul(core.reg(rs2));
                    let acc = core.reg(rd).wrapping_add(v);
                    core.set_reg(rd, acc);
                }
                Inst::FcvtSW { fd, rs1 } => {
                    core.fregs[fd as usize] = core.reg(rs1) as i32 as f32;
                }
                Inst::FcvtWS { rd, fs1 } => {
                    let v = core.fregs[fs1 as usize] as i32 as u32;
                    core.set_reg(rd, v);
                }
                Inst::FmvWX { fd, rs1 } => {
                    core.fregs[fd as usize] = f32::from_bits(core.reg(rs1));
                }
                Inst::FmvXW { rd, fs1 } => {
                    let v = core.fregs[fs1 as usize].to_bits();
                    core.set_reg(rd, v);
                }
                Inst::Fcmp { cond, rd, fs1, fs2 } => {
                    let (a, b) = (core.fregs[fs1 as usize], core.fregs[fs2 as usize]);
                    let t = match cond {
                        Cond::Eq => a == b,
                        Cond::Lt => a < b,
                        _ => a >= b,
                    };
                    core.set_reg(rd, t as u32);
                }
                Inst::CsrR { rd, csr } => {
                    let v = match csr {
                        Csr::MHartId => c_idx as u32,
                        Csr::MClusterId => cl_idx as u32,
                        Csr::MNumCores => n_cores,
                        Csr::ExtAddr => core.ext_addr,
                        Csr::MCycle => now as u32,
                    };
                    core.set_reg(rd, v);
                }
                Inst::HwLoop { l, count, start, end } => {
                    let n = core.reg(count);
                    if n == 0 {
                        finish_step(core, pc, None, end, extra, l0_insts, fetch_pen, now);
                        return;
                    }
                    core.hwloop[l as usize] = HwLoopState { start, end, count: n };
                }
                Inst::Nop => {}
                // Cluster-local memory (falls back when not own-TCDM).
                Inst::Lw { rd, rs1, offset } | Inst::LwPost { rd, rs1, imm: offset } => {
                    let post = matches!(inst, Inst::LwPost { .. });
                    let base = core.reg(rs1);
                    let addr = if post { base } else { base.wrapping_add(offset as u32) };
                    match tcdm_claim_fast!(addr) {
                        Some(off) => {
                            let v = tcdm.mem.load(off);
                            core.set_reg(rd, v);
                            if post {
                                core.set_reg(rs1, base.wrapping_add(offset as u32));
                            }
                        }
                        None => return self.step_core_slow(cl_idx, c_idx),
                    }
                }
                Inst::Flw { fd, rs1, offset } | Inst::FlwPost { fd, rs1, imm: offset } => {
                    let post = matches!(inst, Inst::FlwPost { .. });
                    let base = core.reg(rs1);
                    let addr = if post { base } else { base.wrapping_add(offset as u32) };
                    match tcdm_claim_fast!(addr) {
                        Some(off) => {
                            core.fregs[fd as usize] = f32::from_bits(tcdm.mem.load(off));
                            if post {
                                core.set_reg(rs1, base.wrapping_add(offset as u32));
                            }
                        }
                        None => return self.step_core_slow(cl_idx, c_idx),
                    }
                }
                Inst::Sw { rs2, rs1, offset } | Inst::SwPost { rs2, rs1, imm: offset } => {
                    let post = matches!(inst, Inst::SwPost { .. });
                    let base = core.reg(rs1);
                    let addr = if post { base } else { base.wrapping_add(offset as u32) };
                    match tcdm_claim_fast!(addr) {
                        Some(off) => {
                            tcdm.mem.store(off, core.reg(rs2));
                            if post {
                                core.set_reg(rs1, base.wrapping_add(offset as u32));
                            }
                        }
                        None => return self.step_core_slow(cl_idx, c_idx),
                    }
                }
                Inst::Fsw { fs2, rs1, offset } | Inst::FswPost { fs2, rs1, imm: offset } => {
                    let post = matches!(inst, Inst::FswPost { .. });
                    let base = core.reg(rs1);
                    let addr = if post { base } else { base.wrapping_add(offset as u32) };
                    match tcdm_claim_fast!(addr) {
                        Some(off) => {
                            tcdm.mem.store(off, core.fregs[fs2 as usize].to_bits());
                            if post {
                                core.set_reg(rs1, base.wrapping_add(offset as u32));
                            }
                        }
                        None => return self.step_core_slow(cl_idx, c_idx),
                    }
                }
                // Everything else (remote, DMA, fork/join, CSR writes, AMO,
                // Jalr, Halt, PerfCtl): slow path.
                _ => return self.step_core_slow(cl_idx, c_idx),
            }
            finish_step(core, pc, branch_to, pc + 1, extra, l0_insts, fetch_pen, now);
        }
    }

    fn step_core_slow(&mut self, cl_idx: usize, c_idx: usize) {
        let now = self.now;
        // Resolve wait states first.
        match self.clusters[cl_idx].cores[c_idx].state {
            CoreState::Sleeping | CoreState::Halted | CoreState::WaitBarrier { .. } => return,
            CoreState::WaitDma { id } => {
                let done = self.clusters[cl_idx].dma.completion(id).unwrap_or(0);
                if done <= now {
                    let core = &mut self.clusters[cl_idx].cores[c_idx];
                    core.state = CoreState::Running;
                } else {
                    let core = &mut self.clusters[cl_idx].cores[c_idx];
                    core.perf.bump(Event::DmaWaitCycles);
                    return;
                }
            }
            CoreState::Running => {}
        }
        if self.clusters[cl_idx].cores[c_idx].stall_until > now {
            return;
        }

        let pc = self.clusters[cl_idx].cores[c_idx].pc;
        // --- fetch ---
        let l0_insts = self.cfg.accel.l0_insts as u32;
        let in_l0 = {
            let base = self.clusters[cl_idx].cores[c_idx].l0_base;
            (base..base + l0_insts).contains(&pc)
        };
        if !in_l0 {
            // Fetch from the shared icache.
            let line_insts = self.cfg.accel.icache_line_insts as u32;
            let line = pc / line_insts;
            let n_lines = self.clusters[cl_idx].icache_tags.len();
            let slot = (line as usize) % n_lines;
            if self.clusters[cl_idx].icache_tags[slot] != line {
                // Miss: refill through the fetch port.
                let line_bytes = (line_insts as u64) * 4;
                let dur = self.cfg.timing.icache_refill
                    + line_bytes / self.cfg.ifetch_bytes_per_cycle().max(1);
                let (_, end) = self.clusters[cl_idx].refill_port.acquire(now, dur);
                self.clusters[cl_idx].icache_tags[slot] = line;
                let core = &mut self.clusters[cl_idx].cores[c_idx];
                core.stall_until = end;
                core.perf.bump(Event::IcacheMiss);
                core.perf.add(Event::IFetchStall, end - now);
                return;
            }
        } else {
            self.clusters[cl_idx].cores[c_idx].perf.bump(Event::L0Hit);
        }

        let inst = self.clusters[cl_idx].program.insts[pc as usize];

        // --- execute ---
        // `extra` = stall cycles beyond the base 1-cycle issue.
        let mut extra: u64 = 0;
        let mut next_pc = pc + 1;
        let mut taken_branch_to: Option<u32> = None;

        macro_rules! core {
            () => {
                self.clusters[cl_idx].cores[c_idx]
            };
        }

        match inst {
            Inst::Li { rd, imm } => core!().set_reg(rd, imm as u32),
            Inst::AluImm { op, rd, rs1, imm } => {
                let a = core!().reg(rs1);
                core!().set_reg(rd, alu(op, a, imm as u32));
            }
            Inst::Alu { op, rd, rs1, rs2 } => {
                let (a, b) = (core!().reg(rs1), core!().reg(rs2));
                core!().set_reg(rd, alu(op, a, b));
            }
            Inst::Lw { rd, rs1, offset } | Inst::LwPost { rd, rs1, imm: offset } => {
                let post = matches!(inst, Inst::LwPost { .. });
                let base = core!().reg(rs1);
                let addr = if post { base } else { base.wrapping_add(offset as u32) };
                match self.native_load(cl_idx, c_idx, addr) {
                    NativeAccess::Retry => return,
                    NativeAccess::Done { value, extra: e } => {
                        core!().set_reg(rd, value);
                        if post {
                            core!().set_reg(rs1, base.wrapping_add(offset as u32));
                        }
                        extra += e;
                    }
                }
            }
            Inst::Flw { fd, rs1, offset } | Inst::FlwPost { fd, rs1, imm: offset } => {
                let post = matches!(inst, Inst::FlwPost { .. });
                let base = core!().reg(rs1);
                let addr = if post { base } else { base.wrapping_add(offset as u32) };
                match self.native_load(cl_idx, c_idx, addr) {
                    NativeAccess::Retry => return,
                    NativeAccess::Done { value, extra: e } => {
                        core!().fregs[fd as usize] = f32::from_bits(value);
                        if post {
                            core!().set_reg(rs1, base.wrapping_add(offset as u32));
                        }
                        extra += e;
                    }
                }
            }
            Inst::Sw { rs2, rs1, offset } | Inst::SwPost { rs2, rs1, imm: offset } => {
                let post = matches!(inst, Inst::SwPost { .. });
                let base = core!().reg(rs1);
                let addr = if post { base } else { base.wrapping_add(offset as u32) };
                let val = core!().reg(rs2);
                match self.native_store(cl_idx, c_idx, addr, val) {
                    NativeAccess::Retry => return,
                    NativeAccess::Done { extra: e, .. } => {
                        if post {
                            core!().set_reg(rs1, base.wrapping_add(offset as u32));
                        }
                        extra += e;
                    }
                }
            }
            Inst::Fsw { fs2, rs1, offset } | Inst::FswPost { fs2, rs1, imm: offset } => {
                let post = matches!(inst, Inst::FswPost { .. });
                let base = core!().reg(rs1);
                let addr = if post { base } else { base.wrapping_add(offset as u32) };
                let val = core!().fregs[fs2 as usize].to_bits();
                match self.native_store(cl_idx, c_idx, addr, val) {
                    NativeAccess::Retry => return,
                    NativeAccess::Done { extra: e, .. } => {
                        if post {
                            core!().set_reg(rs1, base.wrapping_add(offset as u32));
                        }
                        extra += e;
                    }
                }
            }
            Inst::Amo { op, rd, rs1, rs2 } => {
                let addr = core!().reg(rs1);
                let b = core!().reg(rs2);
                match self.native_load(cl_idx, c_idx, addr) {
                    NativeAccess::Retry => return,
                    NativeAccess::Done { value, extra: e } => {
                        let new = match op {
                            AmoOp::Swap => b,
                            AmoOp::Add => value.wrapping_add(b),
                            AmoOp::And => value & b,
                            AmoOp::Or => value | b,
                            AmoOp::Max => (value as i32).max(b as i32) as u32,
                            AmoOp::Min => (value as i32).min(b as i32) as u32,
                        };
                        self.store_native_nofail(cl_idx, addr, new);
                        core!().set_reg(rd, value);
                        extra += e + 1;
                    }
                }
            }
            Inst::Branch { cond, rs1, rs2, target } => {
                let (a, b) = (core!().reg(rs1), core!().reg(rs2));
                if branch_taken(cond, a, b) {
                    taken_branch_to = Some(target);
                    extra += self.cfg.timing.branch_taken;
                    core!().perf.bump(Event::BranchTaken);
                }
            }
            Inst::Jal { rd, target } => {
                core!().set_reg(rd, pc + 1);
                taken_branch_to = Some(target);
            }
            Inst::Jalr { rd, rs1, offset } => {
                let t = core!().reg(rs1).wrapping_add(offset as u32);
                core!().set_reg(rd, pc + 1);
                taken_branch_to = Some(t);
            }
            Inst::CsrR { rd, csr } => {
                let v = match csr {
                    Csr::MHartId => c_idx as u32,
                    Csr::MClusterId => cl_idx as u32,
                    Csr::MNumCores => self.clusters[cl_idx].cores.len() as u32,
                    Csr::ExtAddr => core!().ext_addr,
                    Csr::MCycle => now as u32,
                };
                core!().set_reg(rd, v);
            }
            Inst::CsrW { csr, rs1 } => {
                let v = core!().reg(rs1);
                if csr == Csr::ExtAddr {
                    core!().ext_addr = v;
                }
            }
            Inst::Fp { op, fd, fs1, fs2 } => {
                let (a, b) = (core!().fregs[fs1 as usize], core!().fregs[fs2 as usize]);
                core!().fregs[fd as usize] = match op {
                    FpOp::Add => a + b,
                    FpOp::Sub => a - b,
                    FpOp::Mul => a * b,
                    FpOp::Div => a / b,
                    FpOp::Min => a.min(b),
                    FpOp::Max => a.max(b),
                };
            }
            Inst::Fmadd { fd, fs1, fs2, fs3 } => {
                let v = core!().fregs[fs1 as usize] * core!().fregs[fs2 as usize]
                    + core!().fregs[fs3 as usize];
                core!().fregs[fd as usize] = v;
            }
            Inst::Fmac { fd, fs1, fs2 } => {
                let v = core!().fregs[fs1 as usize] * core!().fregs[fs2 as usize];
                core!().fregs[fd as usize] += v;
            }
            Inst::Mac { rd, rs1, rs2 } => {
                let v = core!().reg(rs1).wrapping_mul(core!().reg(rs2));
                let acc = core!().reg(rd).wrapping_add(v);
                core!().set_reg(rd, acc);
            }
            Inst::FcvtSW { fd, rs1 } => {
                core!().fregs[fd as usize] = core!().reg(rs1) as i32 as f32;
            }
            Inst::FcvtWS { rd, fs1 } => {
                let v = core!().fregs[fs1 as usize] as i32 as u32;
                core!().set_reg(rd, v);
            }
            Inst::FmvWX { fd, rs1 } => {
                core!().fregs[fd as usize] = f32::from_bits(core!().reg(rs1));
            }
            Inst::FmvXW { rd, fs1 } => {
                let v = core!().fregs[fs1 as usize].to_bits();
                core!().set_reg(rd, v);
            }
            Inst::Fcmp { cond, rd, fs1, fs2 } => {
                let (a, b) = (core!().fregs[fs1 as usize], core!().fregs[fs2 as usize]);
                let t = match cond {
                    Cond::Eq => a == b,
                    Cond::Lt => a < b,
                    _ => a >= b,
                };
                core!().set_reg(rd, t as u32);
            }
            Inst::LwExt { rd, rs1, offset } => {
                let lo = core!().reg(rs1).wrapping_add(offset as u32);
                let (value, e) = self.remote_load(cl_idx, c_idx, lo);
                core!().set_reg(rd, value);
                extra += e;
            }
            Inst::FlwExt { fd, rs1, offset } => {
                let lo = core!().reg(rs1).wrapping_add(offset as u32);
                let (value, e) = self.remote_load(cl_idx, c_idx, lo);
                core!().fregs[fd as usize] = f32::from_bits(value);
                extra += e;
            }
            Inst::SwExt { rs2, rs1, offset } => {
                let lo = core!().reg(rs1).wrapping_add(offset as u32);
                let val = core!().reg(rs2);
                extra += self.remote_store(cl_idx, c_idx, lo, val);
            }
            Inst::FswExt { fs2, rs1, offset } => {
                let lo = core!().reg(rs1).wrapping_add(offset as u32);
                let val = core!().fregs[fs2 as usize].to_bits();
                extra += self.remote_store(cl_idx, c_idx, lo, val);
            }
            Inst::HwLoop { l, count, start, end } => {
                let n = core!().reg(count);
                if n == 0 {
                    next_pc = end;
                } else {
                    core!().hwloop[l as usize] = HwLoopState { start, end, count: n };
                }
            }
            Inst::DmaStart1D { rd, dir, dev, host_lo, host_hi, bytes } => {
                let d = Descriptor {
                    dir,
                    dev_addr: core!().reg(dev),
                    host_va: ((core!().reg(host_hi) as u64) << 32) | core!().reg(host_lo) as u64,
                    row_bytes: core!().reg(bytes),
                    rows: 1,
                    dev_stride: 0,
                    host_stride: 0,
                    merged: true,
                };
                let (id, e) = self.dma_submit(cl_idx, c_idx, &d);
                core!().set_reg(rd, id);
                extra += e;
            }
            Inst::DmaStart2D {
                rd,
                dir,
                dev,
                host_lo,
                host_hi,
                bytes,
                count,
                dev_stride,
                host_stride,
            } => {
                let d = Descriptor {
                    dir,
                    dev_addr: core!().reg(dev),
                    host_va: ((core!().reg(host_hi) as u64) << 32) | core!().reg(host_lo) as u64,
                    row_bytes: core!().reg(bytes),
                    rows: core!().reg(count),
                    dev_stride: core!().reg(dev_stride),
                    host_stride: core!().reg(host_stride),
                    merged: false,
                };
                let (id, e) = self.dma_submit(cl_idx, c_idx, &d);
                core!().set_reg(rd, id);
                extra += e;
            }
            Inst::DmaWait { rs1 } => {
                let id = core!().reg(rs1);
                let done = self.clusters[cl_idx].dma.completion(id);
                match done {
                    Some(t) if t > now => {
                        // Block; cycles spent blocked are counted per cycle.
                        core!().state = CoreState::WaitDma { id };
                        core!().pc = pc + 1;
                        core!().perf.bump(Event::Instructions);
                        return;
                    }
                    _ => {} // already complete (or unknown/retired): proceed
                }
            }
            Inst::Fork { target } => {
                self.clusters[cl_idx].fork_master = c_idx;
                let (master_regs, master_fregs, master_ext) = {
                    let m = &self.clusters[cl_idx].cores[c_idx];
                    (m.regs, m.fregs, m.ext_addr)
                };
                for w in &mut self.clusters[cl_idx].cores {
                    if w.state == CoreState::Sleeping {
                        w.state = CoreState::Running;
                        w.pc = target;
                        w.l0_base = target;
                        w.regs = master_regs;
                        w.fregs = master_fregs;
                        w.ext_addr = master_ext;
                        w.hwloop = [HwLoopState::default(); 2];
                        w.stall_until = now + FORK_WAKE_CYCLES;
                    }
                }
                taken_branch_to = Some(target);
                extra += 2; // event-unit trigger
            }
            Inst::Join => {
                core!().state = CoreState::WaitBarrier { join: true };
                core!().pc = pc + 1;
                core!().perf.bump(Event::Instructions);
                self.clusters[cl_idx].barrier_waiters += 1;
                return;
            }
            Inst::Barrier => {
                core!().state = CoreState::WaitBarrier { join: false };
                core!().pc = pc + 1;
                core!().perf.bump(Event::Instructions);
                self.clusters[cl_idx].barrier_waiters += 1;
                return;
            }
            Inst::PerfCtl { resume } => {
                for core in &mut self.clusters[cl_idx].cores {
                    core.perf.running = resume;
                }
                // The control write itself is visible regardless of state.
                if resume {
                    self.clusters[cl_idx].cores[c_idx].perf.running = true;
                }
            }
            Inst::Halt => {
                core!().state = CoreState::Halted;
                core!().perf.bump(Event::Instructions);
                return;
            }
            Inst::Nop => {}
        }

        // --- control transfer & hardware loops ---
        if let Some(t) = taken_branch_to {
            next_pc = t;
        }
        // Hardware-loop back-edges (inner loop first).
        if taken_branch_to.is_none() {
            // Inner loop (l0) first; when an inner loop *finishes*, the same
            // address may also be the outer loop's end — keep checking so
            // nested loops with a shared end behave like CV32E40P.
            for l in 0..2 {
                let hl = self.clusters[cl_idx].cores[c_idx].hwloop[l];
                if hl.count > 0 && next_pc == hl.end {
                    let core = &mut self.clusters[cl_idx].cores[c_idx];
                    if hl.count > 1 {
                        core.hwloop[l].count -= 1;
                        next_pc = hl.start;
                        core.perf.bump(Event::HwLoop);
                        // Zero-overhead if the body fits the L0 buffer.
                        if hl.end - hl.start > l0_insts {
                            extra += FETCH_GROUP_BYTES / self.cfg.ifetch_bytes_per_cycle().max(1);
                        }
                        break;
                    }
                    // Loop finished: deactivate and fall through to the
                    // enclosing level (if its end coincides).
                    core.hwloop[l].count = 0;
                }
            }
        }
        // L0 window update & taken-transfer fetch penalty.
        {
            let core = &mut self.clusters[cl_idx].cores[c_idx];
            if next_pc == pc + 1 {
                // Sequential: the window trails execution.
                let min_base = (pc + 1).saturating_sub(l0_insts - 1);
                if core.l0_base < min_base {
                    core.l0_base = min_base;
                }
            } else if taken_branch_to.is_some() {
                let in_window = (core.l0_base..core.l0_base + l0_insts).contains(&next_pc);
                if !in_window {
                    core.l0_base = next_pc;
                    extra += FETCH_GROUP_BYTES / self.cfg.ifetch_bytes_per_cycle().max(1);
                }
            } else if !(core.l0_base..core.l0_base + l0_insts).contains(&next_pc) {
                // Hardware-loop back-edge out of window: move it.
                core.l0_base = next_pc;
            }
            core.pc = next_pc;
            core.perf.bump(Event::Instructions);
            if extra > 0 {
                core.stall_until = now + extra;
            }
        }
    }

    // --- memory helpers ---------------------------------------------------

    fn native_load(&mut self, cl_idx: usize, c_idx: usize, addr: u32) -> NativeAccess {
        match self.decode_native(addr) {
            map::Region::Tcdm(cl, off) if cl == cl_idx => {
                if !self.tcdm_claim(cl_idx, c_idx, off) {
                    return NativeAccess::Retry;
                }
                let v = self.clusters[cl_idx].tcdm.mem.load(off);
                self.clusters[cl_idx].cores[c_idx].perf.bump(Event::TcdmAccess);
                NativeAccess::Done { value: v, extra: 0 }
            }
            map::Region::Tcdm(cl, off) => {
                // Cross-cluster access over the narrow NoC.
                let v = self.clusters[cl].tcdm.mem.load(off);
                let e = self.cfg.timing.l2_access;
                let core = &mut self.clusters[cl_idx].cores[c_idx];
                core.perf.add(Event::LoadStall, e);
                NativeAccess::Done { value: v, extra: e }
            }
            map::Region::L2(off) => {
                let v = self.l2.load(off);
                let e = self.cfg.timing.l2_access - 1;
                let core = &mut self.clusters[cl_idx].cores[c_idx];
                core.perf.bump(Event::L2Access);
                core.perf.add(Event::LoadStall, e);
                NativeAccess::Done { value: v, extra: e }
            }
            map::Region::Unmapped => {
                panic!("core {cl_idx}.{c_idx}: load from unmapped native address {addr:#010x}")
            }
        }
    }

    fn native_store(&mut self, cl_idx: usize, c_idx: usize, addr: u32, val: u32) -> NativeAccess {
        match self.decode_native(addr) {
            map::Region::Tcdm(cl, off) if cl == cl_idx => {
                if !self.tcdm_claim(cl_idx, c_idx, off) {
                    return NativeAccess::Retry;
                }
                self.clusters[cl_idx].tcdm.mem.store(off, val);
                self.clusters[cl_idx].cores[c_idx].perf.bump(Event::TcdmAccess);
                NativeAccess::Done { value: 0, extra: 0 }
            }
            map::Region::Tcdm(cl, off) => {
                self.clusters[cl].tcdm.mem.store(off, val);
                NativeAccess::Done { value: 0, extra: 1 } // posted
            }
            map::Region::L2(off) => {
                self.l2.store(off, val);
                self.clusters[cl_idx].cores[c_idx].perf.bump(Event::L2Access);
                NativeAccess::Done { value: 0, extra: 1 } // posted write
            }
            map::Region::Unmapped => {
                panic!("core {cl_idx}.{c_idx}: store to unmapped native address {addr:#010x}")
            }
        }
    }

    /// Store without conflict modelling (AMO second half; the bank is
    /// already claimed by the AMO's read).
    fn store_native_nofail(&mut self, _cl_idx: usize, addr: u32, val: u32) {
        match self.decode_native(addr) {
            map::Region::Tcdm(cl, off) => self.clusters[cl].tcdm.mem.store(off, val),
            map::Region::L2(off) => self.l2.store(off, val),
            map::Region::Unmapped => panic!("AMO store to unmapped address {addr:#010x}"),
        }
    }

    #[inline]
    fn decode_native(&self, addr: u32) -> map::Region {
        map::decode(
            addr,
            self.clusters.len(),
            self.cfg.accel.l1_bytes as u32,
            self.cfg.accel.l2_bytes as u32,
        )
    }

    /// Try to claim the TCDM bank for `off` this cycle. On conflict, records
    /// the stall and returns false (the core retries next cycle).
    fn tcdm_claim(&mut self, cl_idx: usize, c_idx: usize, off: u32) -> bool {
        let now = self.now;
        let cluster = &mut self.clusters[cl_idx];
        let bank = cluster.tcdm.bank_of(off);
        let skew_conflict = cluster.extra_conflict_ppm > 0 && {
            // Deterministic pseudo-random arbitration skew (§3.3, 128-bit).
            let h = (now ^ (off as u64 ^ ((c_idx as u64) << 17)))
                .wrapping_mul(0x9E37_79B9_7F4A_7C15);
            (h >> 40) % 1_000_000 < cluster.extra_conflict_ppm
        };
        if cluster.bank_claim[bank] == now || skew_conflict {
            let core = &mut cluster.cores[c_idx];
            core.perf.bump(Event::TcdmConflict);
            core.stall_until = now; // retry next cycle
            false
        } else {
            cluster.bank_claim[bank] = now;
            true
        }
    }

    /// Remote load through the ext-address CSR, the narrow NoC and the
    /// IOMMU. Returns (value, extra cycles).
    fn remote_load(&mut self, cl_idx: usize, c_idx: usize, lo: u32) -> (u32, u64) {
        let ext = self.clusters[cl_idx].cores[c_idx].ext_addr;
        let va = ((ext as u64) << 32) | lo as u64;
        let now = self.now;
        let t = self
            .iommu
            .translate(va, &self.pt, now)
            .unwrap_or_else(|| panic!("remote load from unmapped VA {va:#x}"));
        {
            let core = &mut self.clusters[cl_idx].cores[c_idx];
            core.perf.bump(Event::RemoteAccess);
            core.perf.bump(if t.hit { Event::TlbHit } else { Event::TlbMiss });
        }
        let (start, _) = self.clusters[cl_idx]
            .narrow_port
            .acquire(now + t.cost, self.cfg.timing.remote_service);
        let done = start + self.cfg.timing.remote_word;
        let extra = (done - now) + self.cfg.timing.ext_addr_overhead;
        let value = self.dram.port_load(self.narrow_dram_port, t.pa as u32);
        let core = &mut self.clusters[cl_idx].cores[c_idx];
        core.perf.add(Event::LoadStall, extra);
        (value, extra)
    }

    /// Remote store (posted write): the core only pays issue cost.
    fn remote_store(&mut self, cl_idx: usize, c_idx: usize, lo: u32, val: u32) -> u64 {
        let ext = self.clusters[cl_idx].cores[c_idx].ext_addr;
        let va = ((ext as u64) << 32) | lo as u64;
        let now = self.now;
        let t = self
            .iommu
            .translate(va, &self.pt, now)
            .unwrap_or_else(|| panic!("remote store to unmapped VA {va:#x}"));
        {
            let core = &mut self.clusters[cl_idx].cores[c_idx];
            core.perf.bump(Event::RemoteAccess);
            core.perf.bump(if t.hit { Event::TlbHit } else { Event::TlbMiss });
        }
        let (start, _) = self.clusters[cl_idx]
            .narrow_port
            .acquire(now + t.cost, self.cfg.timing.remote_service);
        self.dram.port_store(self.narrow_dram_port, t.pa as u32, val);
        let extra = (start - now) + self.cfg.timing.ext_addr_overhead + 1;
        let core = &mut self.clusters[cl_idx].cores[c_idx];
        core.perf.add(Event::LoadStall, extra);
        extra
    }

    /// Submit a DMA descriptor from outside the simulated cores (host-side
    /// HERO API, tests): data moves and timing is booked on the engine, but
    /// no core pays setup stalls.
    pub fn dma_submit_external(&mut self, cl_idx: usize, d: &Descriptor) -> Result<u32> {
        if cl_idx >= self.clusters.len() {
            bail!("no such cluster {cl_idx}");
        }
        // Book on core 0: no core pays setup stalls for external transfers.
        let (id, _) = self.dma_submit(cl_idx, 0, d);
        Ok(id)
    }

    /// Submit a DMA descriptor: move the data functionally, enqueue on the
    /// cluster engine (which routes the DRAM side through its shared-DRAM
    /// port), book perf events on `c_idx`, and return the programming
    /// core's `setup_cycles` stall.
    fn dma_submit(&mut self, cl_idx: usize, c_idx: usize, d: &Descriptor) -> (u32, u64) {
        let translate_cost = self.dma_move_data(d);
        let now = self.now;
        let Accel { clusters, dram, .. } = self;
        let cluster = &mut clusters[cl_idx];
        let setup = cluster.dma.setup_cycles();
        let busy_before = cluster.dma.stats.busy_cycles;
        let stall_before = cluster.dma.stats.dram_stall_cycles;
        let (id, _done_at) = cluster.dma.enqueue(now + setup, d, translate_cost, dram);
        let busy = cluster.dma.stats.busy_cycles - busy_before;
        let stall = cluster.dma.stats.dram_stall_cycles - stall_before;
        let core = &mut cluster.cores[c_idx];
        core.perf.bump(Event::DmaTransfers);
        core.perf.add(Event::DmaBursts, d.bursts());
        core.perf.add(Event::DmaBytes, d.total_bytes());
        core.perf.add(Event::DmaBusyCycles, busy);
        core.perf.add(Event::DmaDramStall, stall);
        (id, setup)
    }

    /// Functional data movement + IOMMU cost accumulation for a descriptor.
    fn dma_move_data(&mut self, d: &Descriptor) -> u64 {
        assert!(d.row_bytes % 4 == 0 && d.dev_addr % 4 == 0 && d.host_va % 4 == 0,
            "DMA requires word alignment (dev {:#x}, host {:#x}, {} B rows)",
            d.dev_addr, d.host_va, d.row_bytes);
        let now = self.now;
        let mut translate_cost = 0u64;
        let page = self.cfg.iommu.page_bytes as u64;
        let mut buf: Vec<u32> = Vec::new();
        for row in 0..d.rows as u64 {
            let dev = d.dev_addr as u64 + row * d.dev_stride as u64;
            let host = d.host_va + row * d.host_stride as u64;
            let mut done = 0u64;
            while done < d.row_bytes as u64 {
                let chunk = (page - (host + done) % page).min(d.row_bytes as u64 - done);
                let t = self
                    .iommu
                    .translate(host + done, &self.pt, now)
                    .unwrap_or_else(|| panic!("DMA touches unmapped VA {:#x}", host + done));
                translate_cost += t.cost;
                let words = (chunk / 4) as usize;
                buf.resize(words, 0);
                match d.dir {
                    DmaDir::HostToDev => {
                        self.dram.mem.read_words(t.pa as u32, &mut buf);
                        self.write_dev_words((dev + done) as u32, &buf);
                    }
                    DmaDir::DevToHost => {
                        self.read_dev_words((dev + done) as u32, &mut buf);
                        self.dram.mem.write_words(t.pa as u32, &buf);
                    }
                }
                done += chunk;
            }
        }
        translate_cost
    }

    fn write_dev_words(&mut self, addr: u32, data: &[u32]) {
        match self.decode_native(addr) {
            map::Region::Tcdm(cl, off) => self.clusters[cl].tcdm.mem.write_words(off, data),
            map::Region::L2(off) => self.l2.write_words(off, data),
            map::Region::Unmapped => panic!("DMA to unmapped device address {addr:#010x}"),
        }
    }

    fn read_dev_words(&mut self, addr: u32, out: &mut [u32]) {
        match self.decode_native(addr) {
            map::Region::Tcdm(cl, off) => self.clusters[cl].tcdm.mem.read_words(off, out),
            map::Region::L2(off) => self.l2.read_words(off, out),
            map::Region::Unmapped => panic!("DMA from unmapped device address {addr:#010x}"),
        }
    }
}

enum NativeAccess {
    /// Bank conflict: retry next cycle without executing.
    Retry,
    Done {
        value: u32,
        extra: u64,
    },
}

fn alu(op: AluOp, a: u32, b: u32) -> u32 {
    match op {
        AluOp::Add => a.wrapping_add(b),
        AluOp::Sub => a.wrapping_sub(b),
        AluOp::Mul => a.wrapping_mul(b),
        AluOp::Mulhu => ((a as u64 * b as u64) >> 32) as u32,
        AluOp::Div => {
            if b == 0 {
                u32::MAX
            } else {
                ((a as i32).wrapping_div(b as i32)) as u32
            }
        }
        AluOp::Rem => {
            if b == 0 {
                a
            } else {
                ((a as i32).wrapping_rem(b as i32)) as u32
            }
        }
        AluOp::And => a & b,
        AluOp::Or => a | b,
        AluOp::Xor => a ^ b,
        AluOp::Sll => a.wrapping_shl(b & 31),
        AluOp::Srl => a.wrapping_shr(b & 31),
        AluOp::Sra => ((a as i32).wrapping_shr(b & 31)) as u32,
        AluOp::Slt => ((a as i32) < (b as i32)) as u32,
        AluOp::Sltu => (a < b) as u32,
        AluOp::Min => ((a as i32).min(b as i32)) as u32,
        AluOp::Max => ((a as i32).max(b as i32)) as u32,
    }
}

#[inline(always)]
fn cluster_fast_mask_get(mask: &[bool], pc: u32) -> bool {
    mask.get(pc as usize).copied().unwrap_or(false)
}

/// Shared step epilogue: hardware-loop back-edges, L0 window maintenance,
/// fetch penalties on taken control transfers, pc/stall/instruction-count
/// update. Exactly mirrors the slow path's inline epilogue.
#[inline]
fn finish_step(
    core: &mut crate::cluster::Core,
    pc: u32,
    branch_to: Option<u32>,
    initial_next: u32,
    mut extra: u64,
    l0_insts: u32,
    fetch_pen: u64,
    now: u64,
) {
    let mut next_pc = initial_next;
    if let Some(t) = branch_to {
        next_pc = t;
    } else {
        for l in 0..2 {
            let hl = core.hwloop[l];
            if hl.count > 0 && next_pc == hl.end {
                if hl.count > 1 {
                    core.hwloop[l].count -= 1;
                    next_pc = hl.start;
                    core.perf.bump(Event::HwLoop);
                    if hl.end - hl.start > l0_insts {
                        extra += fetch_pen;
                    }
                    break;
                }
                core.hwloop[l].count = 0;
            }
        }
    }
    if next_pc == pc + 1 {
        let min_base = (pc + 1).saturating_sub(l0_insts - 1);
        if core.l0_base < min_base {
            core.l0_base = min_base;
        }
    } else if branch_to.is_some() {
        let in_window = (core.l0_base..core.l0_base + l0_insts).contains(&next_pc);
        if !in_window {
            core.l0_base = next_pc;
            extra += fetch_pen;
        }
    } else if !(core.l0_base..core.l0_base + l0_insts).contains(&next_pc) {
        core.l0_base = next_pc;
    }
    core.pc = next_pc;
    core.perf.bump(Event::Instructions);
    if extra > 0 {
        core.stall_until = now + extra;
    }
}

fn branch_taken(cond: Cond, a: u32, b: u32) -> bool {
    match cond {
        Cond::Eq => a == b,
        Cond::Ne => a != b,
        Cond::Lt => (a as i32) < (b as i32),
        Cond::Ge => (a as i32) >= (b as i32),
        Cond::Ltu => a < b,
        Cond::Geu => a >= b,
    }
}

/// Convenience: build an Aurora-config accelerator with `dram_bytes`.
pub fn aurora_accel(dram_bytes: usize) -> Accel {
    Accel::new(crate::config::aurora(), dram_bytes)
}

// Re-export for integration tests.
pub use crate::cluster::CoreState as AccelCoreState;

#[allow(unused)]
fn _context_helper() -> Result<()> {
    // Keep `Context` imported for future use without a warning.
    Option::<()>::Some(()).context("ok")?;
    Ok(())
}

#[cfg(test)]
mod tests;
