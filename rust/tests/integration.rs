//! Integration tests: the whole stack composed — repeated offloads,
//! multi-cluster teams, figure-harness smoke runs, and the three-layer
//! PJRT verification when artifacts are present.

use herov2::accel::Accel;
use herov2::bench_harness::{self, figures, run_workload, verify, Variant};
use herov2::compiler::{compile, ir::*, LowerOpts};
use herov2::config::{aurora, cyclone};
use herov2::host::HostContext;
use herov2::runtime::omp::offload;
use herov2::runtime::pjrt::PjrtRuntime;
use herov2::trace::Event;
use herov2::workloads;

#[test]
fn back_to_back_offloads_reuse_the_accelerator() {
    // The driver reloads programs between offloads; state must not leak.
    let cfg = aurora();
    let w = workloads::gemm::build(16);
    let opts = LowerOpts::for_config(&cfg);
    let (lowered, _) = compile(&w.handwritten, &opts, None).unwrap();
    let mut accel = Accel::new(cfg, 1 << 20);
    let mut host = HostContext::new();
    let data = w.gen_data(9);
    let bufs: Vec<_> =
        w.arrays.iter().map(|a| host.alloc(&mut accel, a.elems).unwrap()).collect();
    let mut last = Vec::new();
    for round in 0..3 {
        for (b, d) in bufs.iter().zip(&data) {
            host.write_f32(&mut accel, b, d);
        }
        let refs: Vec<_> = bufs.iter().collect();
        let res = offload(&mut accel, &lowered, &refs, &w.fargs, 1, 1_000_000_000).unwrap();
        assert!(res.device_cycles > 0, "round {round}");
        let c = host.read_f32(&accel, &bufs[2]);
        if round > 0 {
            assert_eq!(c, last, "offloads must be reproducible (round {round})");
        }
        last = c;
    }
}

#[test]
fn teams_distribute_uses_multiple_clusters() {
    // A Cyclone-style 4-cluster accelerator runs a teams-distributed kernel:
    // each cluster scales its own strip of Y.
    let cfg = cyclone();
    let n = 1024i32;
    let mut b = KernelBuilder::new("scale_teams");
    let x = b.host_array("X", vec![ci(n)]);
    let y = b.host_array("Y", vec![ci(n)]);
    let a = b.float_param("a");
    let i = b.loop_var("i");
    let j = b.loop_var("j");
    let k = b.body(vec![Stmt::For {
        var: i,
        lo: ci(0),
        hi: ci(4),
        par: Par::Teams,
        body: vec![Stmt::For {
            var: j,
            lo: ci(0),
            hi: ci(n / 4),
            par: Par::Cores,
            body: vec![st(
                y,
                vec![var(i).mul(ci(n / 4)).add(var(j))],
                var(a).mul(ld(x, vec![var(i).mul(ci(n / 4)).add(var(j))])),
            )],
        }],
    }]);
    let (lowered, _) = compile(&k, &LowerOpts::for_config(&cfg), None).unwrap();
    let mut accel = Accel::new(cfg, 1 << 20);
    let mut host = HostContext::new();
    let xb = host.alloc(&mut accel, 1024).unwrap();
    let yb = host.alloc(&mut accel, 1024).unwrap();
    let xs: Vec<f32> = (0..1024).map(|i| i as f32 * 0.25).collect();
    host.write_f32(&mut accel, &xb, &xs);
    offload(&mut accel, &lowered, &[&xb, &yb], &[2.0], 4, 100_000_000).unwrap();
    let got = host.read_f32(&accel, &yb);
    for i in 0..1024 {
        assert_eq!(got[i], 0.5 * i as f32, "Y[{i}]");
    }
    // All four clusters must have executed instructions.
    for cl in 0..4 {
        let instr = accel.clusters[cl].perf_aggregate().get(Event::Instructions);
        assert!(instr > 100, "cluster {cl} idle ({instr} instructions)");
    }
}

#[test]
fn figure_harness_smoke_tiny() {
    // Every figure function runs end to end on tiny sizes.
    std::env::set_var("HERO_FAST", "1");
    let cfg = aurora();
    let f4 = figures::fig4(&cfg).unwrap();
    assert_eq!(f4.len(), 8);
    assert!(f4.iter().all(|r| r.speedup > 1.0), "tiling must help even tiny sizes");
    let f5 = figures::fig5(&cfg).unwrap();
    assert!(f5.iter().all(|r| r.overall_speedup > 1.0));
    let f7 = figures::fig7(&cfg).unwrap();
    assert!(f7.iter().all(|r| r.autodma_speedup > 0.5));
    let f9 = figures::fig9(&cfg).unwrap();
    assert!(f9.iter().all(|r| r.xpulp_speedup > 1.0), "Xpulpv2 must not hurt");
    std::env::remove_var("HERO_FAST");
}

#[test]
fn gemm_inner_loop_matches_paper_instruction_counts() {
    // §3.4: gemm base inner loop = 10 instructions (2 loads, 4 additions,
    // 2 multiplications, 1 store, 1 branch); Xpulpv2 = 5 (2 post-increment
    // loads, 1 mul, 1 MAC, 1 store); manual promotion = 4.
    let w = workloads::gemm::build(128);
    let mut base = aurora();
    base.accel.isa.xpulp = false;
    let opts_b = LowerOpts::for_config(&base);
    let opts_x = LowerOpts::for_config(&aurora());
    let (lb, _) = compile(&w.handwritten, &opts_b, None).unwrap();
    let (lx, _) = compile(&w.handwritten, &opts_x, None).unwrap();
    let (lp, _) = compile(w.promoted.as_ref().unwrap(), &opts_x, None).unwrap();
    assert_eq!(figures::inner_loop_len(&lb.program), 10, "base ISA inner loop");
    assert_eq!(figures::inner_loop_len(&lx.program), 5, "Xpulpv2 inner loop");
    assert_eq!(figures::inner_loop_len(&lp.program), 4, "promoted inner loop");
}

#[test]
fn covar_alias_pair_defeats_hwloop_inference() {
    // §3.4: covar's symmetric in-loop store is a may-alias pair that
    // defeats hardware-loop inference (and accumulator caching). The
    // unmodified covar carries that pattern; verify no inferred hardware
    // loop ever contains two stores (the alias-carrying reduction stays a
    // branch loop), while gemm's clean reduction gets its two hardware
    // loops (§3.4: "the compiler replaces the inner two compute loops by
    // hardware loops").
    use herov2::isa::Inst;
    let opts = LowerOpts::for_config(&aurora());
    let covar = workloads::covar::build(24);
    let (cov, _) = compile(&covar.unmodified, &opts, None).unwrap();
    for inst in &cov.program.insts {
        if let Inst::HwLoop { start, end, .. } = inst {
            let stores = cov.program.insts[*start as usize..*end as usize]
                .iter()
                .filter(|i| {
                    matches!(
                        i,
                        Inst::Fsw { .. }
                            | Inst::FswPost { .. }
                            | Inst::FswExt { .. }
                            | Inst::Sw { .. }
                    )
                })
                .count();
            assert!(stores <= 1, "alias-carrying loop became a hardware loop");
        }
    }
    let gemm = workloads::gemm::build(24);
    let (g, _) = compile(&gemm.handwritten, &opts, None).unwrap();
    let hwloops =
        g.program.insts.iter().filter(|i| matches!(i, Inst::HwLoop { .. })).count();
    assert!(hwloops >= 2, "gemm must get its two hardware loops, got {hwloops}");
    // Manual promotion on the handwritten tile kernel still pays: the
    // store leaves the inner loop (Fig 9 bar 2).
    let (prom, _) = compile(covar.promoted.as_ref().unwrap(), &opts, None).unwrap();
    use herov2::bench_harness::figures::inner_loop_len;
    assert!(
        inner_loop_len(&prom.program) < inner_loop_len(&cov.program),
        "promotion must shrink the inner loop"
    );
}

#[test]
fn atax_column_walk_gets_no_post_increment() {
    // §3.4: "for atax, the increment of one of the two loads in the
    // innermost loop is too large to be used in post-increment" (the column
    // stride at N=512 is 2048 B, beyond the 12-bit immediate).
    use herov2::isa::Inst;
    let w = workloads::atax::build(512);
    let opts = LowerOpts::for_config(&aurora());
    let (lowered, _) = compile(&w.handwritten, &opts, None).unwrap();
    let has_big_post = lowered.program.insts.iter().any(|i| match i {
        Inst::FlwPost { imm, .. } | Inst::LwPost { imm, .. } => imm.abs() >= 2048,
        _ => false,
    });
    assert!(!has_big_post, "post-increment must not encode >= 2 KiB strides");
}

#[test]
fn pjrt_three_layer_verification_when_built() {
    // Simulated RV32 accelerator vs XLA-executed JAX/Pallas artifacts.
    let mut rt = match PjrtRuntime::new(PjrtRuntime::default_dir()) {
        Ok(rt) => rt,
        Err(_) => return, // PJRT plugin unavailable
    };
    let cfg = aurora();
    let mut checked = 0;
    for w in workloads::all_tiny() {
        if !rt.available(&w.pjrt.name) {
            continue;
        }
        let out = run_workload(&cfg, &w, Variant::Handwritten, 8, 21, 10_000_000_000).unwrap();
        verify(&w, &out, 21).unwrap();
        let ok = bench_harness::verify_pjrt(&mut rt, &w, &out, 21)
            .unwrap_or_else(|e| panic!("{}: {e}", w.name));
        assert!(ok);
        checked += 1;
    }
    if checked > 0 {
        println!("PJRT-verified {checked} workloads");
    }
}
