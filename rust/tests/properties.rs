//! Property-based tests over the coordinator's invariants: allocator
//! soundness, DMA scatter/gather correctness, IOMMU translation, NoC port
//! serialization, compiler semantic preservation across random problem
//! sizes (which sweeps ragged tile edges), and config-file round-trips.

use herov2::accel::Accel;
use herov2::bench_harness::{run_workload, verify, Variant};
use herov2::config::{aurora, parse};
use herov2::dma::Descriptor;
use herov2::iommu::{Iommu, PageTable};
use herov2::isa::DmaDir;
use herov2::mem::o1heap::{FreeResult, O1Heap};
use herov2::noc::Port;
use herov2::testkit::{check, Rng};
use herov2::workloads;
use std::collections::HashMap;

#[test]
fn prop_o1heap_random_alloc_free_never_overlaps() {
    check(
        60,
        |rng| {
            let ops: Vec<(bool, u32)> =
                (0..40).map(|_| (rng.bool(), rng.range(1, 700) as u32)).collect();
            ops
        },
        |ops| {
            let mut mem: HashMap<u32, u32> = HashMap::new();
            let mut h = O1Heap::new(1024, 16 * 1024);
            let mut live: Vec<(u32, u32)> = Vec::new();
            for (is_alloc, size) in ops {
                if *is_alloc {
                    if let Some(a) = h.malloc(*size, |o, v| {
                        mem.insert(o, v);
                    }) {
                        for &(b, bs) in &live {
                            if a < b + bs && b < a + size {
                                return Err(format!("overlap ({a},{size}) vs ({b},{bs})"));
                            }
                        }
                        if a < 1024 || a + size > 1024 + 16 * 1024 {
                            return Err(format!("block ({a},{size}) outside region"));
                        }
                        live.push((a, *size));
                    }
                } else if let Some((a, _)) = live.pop() {
                    if h.free(a, |o| mem[&o]) != FreeResult::Ok {
                        return Err(format!("canary failed for untouched block {a}"));
                    }
                }
            }
            // Free the rest: full capacity must come back (coalescing).
            for (a, _) in live {
                h.free(a, |o| mem[&o]);
            }
            if h.capacity_remaining() != 16 * 1024 {
                return Err(format!("leak: {} != {}", h.capacity_remaining(), 16 * 1024));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dma_2d_gather_matches_reference() {
    check(
        40,
        |rng| {
            let rows = rng.usize(1, 12) as u32;
            let elems = rng.usize(1, 24) as u32;
            let host_pitch = elems + rng.usize(0, 16) as u32;
            let dev_pitch = elems + rng.usize(0, 8) as u32;
            (rows, elems, host_pitch, dev_pitch, rng.range(0, 1) == 1)
        },
        |&(rows, elems, host_pitch, dev_pitch, to_dev)| {
            let mut accel = Accel::new(aurora(), 1 << 20);
            accel.pt.map_range(0x40_0000_0000, 0, 1 << 19);
            // Fill both sides with distinct patterns.
            for i in 0..(1 << 16) {
                accel.dram.mem.store(i * 4, 0xA000_0000 | i);
                accel.clusters[0].tcdm.mem.store(i % (1 << 15) * 4, 0xB000_0000 | i);
            }
            let d = Descriptor {
                dir: if to_dev { DmaDir::HostToDev } else { DmaDir::DevToHost },
                dev_addr: herov2::mem::map::TCDM_BASE + 64,
                host_va: 0x40_0000_0000 + 128,
                row_bytes: elems * 4,
                rows,
                dev_stride: dev_pitch * 4,
                host_stride: host_pitch * 4,
                merged: false,
            };
            let snapshot_dram: Vec<u32> =
                (0..4096).map(|i| accel.dram.mem.load(i * 4)).collect();
            let snapshot_tcdm: Vec<u32> =
                (0..4096).map(|i| accel.clusters[0].tcdm.mem.load(i * 4)).collect();
            accel.dma_submit_external(0, &d).map_err(|e| e.to_string())?;
            for r in 0..rows {
                for c in 0..elems {
                    let dev_w = (64 / 4) + r * dev_pitch + c;
                    let host_w = (128 / 4) + r * host_pitch + c;
                    let dev_v = accel.clusters[0].tcdm.mem.load(dev_w * 4);
                    let host_v = accel.dram.mem.load(host_w * 4);
                    if to_dev {
                        if dev_v != snapshot_dram[host_w as usize] {
                            return Err(format!("gather row {r} col {c}: {dev_v:#x}"));
                        }
                    } else if host_v != snapshot_tcdm[dev_w as usize] {
                        return Err(format!("scatter row {r} col {c}: {host_v:#x}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_iommu_translation_matches_page_table() {
    check(
        50,
        |rng| {
            let vas: Vec<u64> =
                (0..30).map(|_| 0x40_0000_0000u64 + rng.range(0, (1 << 20) - 4)).collect();
            vas
        },
        |vas| {
            let cfg = aurora();
            let mut pt = PageTable::new(cfg.iommu.page_bytes);
            pt.map_range(0x40_0000_0000, 0x20_0000, 1 << 20);
            let mut io = Iommu::new(cfg.iommu);
            for (i, va) in vas.iter().enumerate() {
                let t = io
                    .translate(*va, &pt, i as u64)
                    .ok_or_else(|| format!("unmapped {va:#x}"))?;
                let want = pt.walk(*va).unwrap();
                if t.pa != want {
                    return Err(format!("{va:#x}: {:#x} != {want:#x}", t.pa));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_noc_port_serializes_and_conserves_busy_time() {
    check(
        50,
        |rng| {
            let reqs: Vec<(u64, u64)> =
                (0..20).map(|_| (rng.range(0, 1000), rng.range(1, 50))).collect();
            reqs
        },
        |reqs| {
            let mut p = Port::new();
            let mut prev_end = 0u64;
            let mut total = 0u64;
            let mut t = 0u64;
            for (dt, dur) in reqs {
                t += dt;
                let (start, end) = p.acquire(t, *dur);
                if start < t || start < prev_end {
                    return Err(format!("overlap: start {start} < max({t}, {prev_end})"));
                }
                if end - start != *dur {
                    return Err("duration not honored".into());
                }
                prev_end = end;
                total += dur;
            }
            if p.busy_cycles != total {
                return Err(format!("busy {} != {total}", p.busy_cycles));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_compiler_preserves_semantics_across_sizes() {
    // Random problem sizes sweep ragged strips/tiles; every variant must
    // still match the host golden model bit-for-bit.
    check(
        10,
        |rng| {
            let which = rng.usize(0, 3);
            let n = rng.usize(5, 28);
            (which, n, rng.range(1, 1 << 30))
        },
        |&(which, n, seed)| {
            let w = match which {
                0 => workloads::gemm::build(n),
                1 => workloads::atax::build(n.max(6)),
                2 => workloads::conv2d::build(n.max(8)),
                _ => workloads::darknet::build(n),
            };
            let cfg = aurora();
            for variant in
                [Variant::Unmodified, Variant::Handwritten, Variant::Promoted, Variant::AutoDma]
            {
                let out = run_workload(&cfg, &w, variant, 8, seed, 10_000_000_000)
                    .map_err(|e| format!("{} {}: {e}", w.name, variant.label()))?;
                verify(&w, &out, seed)
                    .map_err(|e| format!("{} {}: {e}", w.name, variant.label()))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_xpulp_and_base_isa_agree() {
    // Xpulpv2 codegen (hwloops, post-increment, MAC) must not change
    // results relative to the base-ISA lowering.
    check(
        8,
        |rng| (rng.usize(6, 24), rng.range(1, 1 << 30)),
        |&(n, seed)| {
            let w = workloads::gemm::build(n);
            let mut base = aurora();
            base.accel.isa.xpulp = false;
            let a = run_workload(&base, &w, Variant::Handwritten, 8, seed, 10_000_000_000)
                .map_err(|e| e.to_string())?;
            let b = run_workload(&aurora(), &w, Variant::Handwritten, 8, seed, 10_000_000_000)
                .map_err(|e| e.to_string())?;
            if a.arrays != b.arrays {
                return Err("base ISA and Xpulpv2 disagree".into());
            }
            // And Xpulpv2 must not be slower.
            if b.cycles() > a.cycles() {
                return Err(format!("xpulp slower: {} > {}", b.cycles(), a.cycles()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sched_results_identical_across_policies_and_pools() {
    // Scheduling moves *time*, never numerics: the same job stream must
    // produce bit-identical per-job results (hence an identical digest)
    // under any policy, pool size, batching or caching configuration.
    use herov2::sched::{Policy, Scheduler};
    use herov2::workloads::synth;
    check(
        2,
        |rng| (rng.usize(4, 6), rng.range(1, 1 << 20)),
        |&(n, seed)| {
            let jobs = synth::tiny_jobs(n, seed);
            let mut digests = Vec::new();
            for (policy, pool, cache, batch) in [
                (Policy::Fifo, 1usize, true, false),
                (Policy::Sjf, 3, true, true),
                (Policy::parse("cap-reject").unwrap(), 2, false, true),
            ] {
                let mut s = Scheduler::new(aurora(), pool, policy)
                    .with_cache(cache)
                    .with_batching(batch);
                let handles = s.submit_all(&jobs);
                s.drain().map_err(|e| e.to_string())?;
                let r = s.report();
                if r.completed != jobs.len() {
                    return Err(format!(
                        "{}: only {} of {} jobs completed",
                        policy.label(),
                        r.completed,
                        jobs.len()
                    ));
                }
                if r.verify_failures != 0 {
                    return Err(format!("{}: golden-model mismatch", policy.label()));
                }
                if handles.iter().any(|h| !s.state(*h).is_some_and(|st| st.settled())) {
                    return Err(format!("{}: unsettled handle", policy.label()));
                }
                digests.push(r.digest);
            }
            if digests.windows(2).any(|w| w[0] != w[1]) {
                return Err(format!("digests diverge across configurations: {digests:#x?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sched_no_submitted_job_starves() {
    // Every handle must settle (Done, Rejected or Split) once the queue is
    // drained — including oversized jobs under capacity pressure and
    // long jobs that SJF keeps pushing behind shorter ones.
    use herov2::bench_harness::Variant;
    use herov2::sched::{JobDesc, JobHandle, OversizeAction, Policy, Scheduler};
    use herov2::workloads::synth;
    check(
        2,
        |rng| (rng.usize(3, 5), rng.range(1, 1 << 20), rng.bool()),
        |&(n, seed, sjf)| {
            let mut cfg = aurora();
            cfg.accel.l1_bytes = 16 * 1024; // shrink L1 to pressure admission
            let policy =
                if sjf { Policy::Sjf } else { Policy::Capacity(OversizeAction::Split) };
            let mut s = Scheduler::new(cfg, 2, policy).with_verify(false);
            s.submit_all(&synth::tiny_jobs(n, seed));
            // An oversized job: the capacity policy must split it into
            // feasible sub-jobs; SJF (no admission) must still settle it
            // (rejected at dispatch when its tiling overflows L1).
            s.submit(JobDesc {
                kernel: "gemm",
                size: 64,
                variant: Variant::Handwritten,
                threads: 8,
                seed,
                arrival: 0,
                priority: herov2::sched::Priority::Normal,
            });
            s.drain().map_err(|e| e.to_string())?;
            for id in 0..s.submitted() {
                if !s.state(JobHandle(id)).is_some_and(|st| st.settled()) {
                    return Err(format!("job {id} never settled"));
                }
            }
            if s.pending() != 0 {
                return Err(format!("{} jobs left in the queue", s.pending()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dram_ledger_conserves_bytes_and_respects_peak() {
    // The shared-DRAM bandwidth ledger must (a) account every requested
    // byte exactly once, (b) never reserve above its peak anywhere on the
    // timeline, and (c) never finish a request before its uncontended
    // service time.
    use herov2::mem::BandwidthLedger;
    check(
        60,
        |rng| {
            let peak = rng.range(2, 48);
            let headroom = rng.range(0, peak / 2);
            let reqs: Vec<(u64, u64, u64, bool)> = (0..25)
                .map(|_| {
                    (rng.range(0, 2000), rng.range(1, 8192), rng.range(1, 16), rng.bool())
                })
                .collect();
            (peak, headroom, reqs)
        },
        |(peak, headroom, reqs)| {
            let mut l = BandwidthLedger::new(*peak, *headroom);
            let mut sum = 0u64;
            for &(start, bytes, rate, prio) in reqs {
                let end = l.reserve(start, bytes, rate, prio);
                let floor = l.uncontended_cycles(bytes, rate, prio);
                if end < start + floor {
                    return Err(format!(
                        "request ({start}, {bytes} B, {rate} B/cy) finished at {end}, \
                         before its uncontended time {floor}"
                    ));
                }
                sum += bytes;
            }
            if l.total_bytes() != sum {
                return Err(format!("served {} B != requested {sum} B", l.total_bytes()));
            }
            if l.max_rate() > *peak {
                return Err(format!("reserved rate {} exceeds peak {peak}", l.max_rate()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pool_conserves_dram_beats_and_pool1_matches_uncontended() {
    // Scheduler-level conservation: every byte a job moved through the
    // board DRAM shows up exactly once in the ledger, the per-instance
    // stats, and the per-job outcomes. And the pool=1 identity: with the
    // board peak covering a single instance's drain rate, contention
    // accounting adds zero cycles — makespan and digest are identical to
    // the uncontended board.
    use herov2::sched::{BoardSpec, JobHandle, Policy, Scheduler};
    use herov2::workloads::synth;
    check(
        2,
        |rng| (rng.usize(4, 6), rng.range(1, 1 << 20)),
        |&(n, seed)| {
            let jobs = synth::tiny_jobs(n, seed);
            let cfg = aurora();
            let beat = cfg.dma_beat_bytes();
            let run = |board: BoardSpec| {
                let mut s = Scheduler::new(aurora(), 1, Policy::Fifo).with_verify(false);
                s = s.with_board(board);
                s.submit_all(&jobs);
                s.drain().map_err(|e| e.to_string())?;
                Ok::<_, String>(s)
            };
            let open = run(BoardSpec::uncontended())?;
            let capped = run(BoardSpec::with_bandwidth(beat))?;
            let ro = open.report();
            let rc = capped.report();
            if rc.makespan_cycles != ro.makespan_cycles {
                return Err(format!(
                    "pool=1 contended makespan {} != uncontended {}",
                    rc.makespan_cycles, ro.makespan_cycles
                ));
            }
            if rc.digest != ro.digest {
                return Err("pool=1 digest diverged under contention accounting".into());
            }
            if rc.dram_stall_cycles != 0 {
                return Err(format!("pool=1 stalled {} cycles", rc.dram_stall_cycles));
            }
            // Conservation across all three books.
            let per_inst: u64 = rc.instances.iter().map(|i| i.dram_bytes).sum();
            let per_job: u64 = (0..capped.submitted())
                .filter_map(|i| capped.poll(JobHandle(i)).map(|o| o.dma_bytes))
                .sum();
            if rc.dram_bytes != per_inst || rc.dram_bytes != per_job {
                return Err(format!(
                    "DRAM byte books disagree: ledger {} vs instances {per_inst} vs jobs {per_job}",
                    rc.dram_bytes
                ));
            }
            if per_job == 0 {
                return Err("tiled jobs must move DMA bytes".into());
            }
            for i in 0..capped.submitted() {
                if !capped.state(JobHandle(i)).is_some_and(|st| st.settled()) {
                    return Err(format!("job {i} never settled"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pressure_placement_identical_to_earliest_free_on_uncontended_board() {
    // The placement engine's safety identity: with no board contention the
    // pressure score is a monotone transform of free_at, so the *entire
    // assignment sequence* — every dispatch and completion event, every
    // instance choice, makespan and digest — is bit-identical to
    // earliest-free, under FIFO and SJF alike.
    use herov2::sched::{BoardSpec, Placement, Policy, Scheduler};
    use herov2::workloads::synth;
    check(
        2,
        |rng| (rng.usize(4, 6), rng.range(1, 1 << 20), rng.usize(2, 3), rng.bool()),
        |&(n, seed, pool, sjf)| {
            let jobs = synth::tiny_jobs(n, seed);
            let policy = if sjf { Policy::Sjf } else { Policy::Fifo };
            let run = |placement: Placement| {
                let mut s = Scheduler::new(aurora(), pool, policy)
                    .with_placement(placement)
                    .with_board(BoardSpec::uncontended())
                    .with_verify(false);
                s.submit_all(&jobs);
                s.drain().map_err(|e| e.to_string())?;
                Ok::<_, String>(s)
            };
            let ef = run(Placement::EarliestFree)?;
            let pr = run(Placement::Pressure)?;
            if ef.trace.events != pr.trace.events {
                return Err("dispatch sequences diverged on an uncontended board".into());
            }
            let (re, rp) = (ef.report(), pr.report());
            if re.makespan_cycles != rp.makespan_cycles {
                return Err(format!(
                    "makespan diverged: {} vs {}",
                    re.makespan_cycles, rp.makespan_cycles
                ));
            }
            if re.digest != rp.digest {
                return Err("digest diverged (placement must never touch numerics)".into());
            }
            for i in 0..pool {
                if re.instances[i].busy_cycles != rp.instances[i].busy_cycles {
                    return Err(format!("instance {i} busy cycles diverged"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_priority_job_turnaround_never_worse_under_contention() {
    // Marking one job latency-critical must never hurt that job: on the
    // same stream/seed over a bandwidth-constrained board, its turnaround
    // with `Priority::High` is <= its turnaround as a normal job. The
    // priority job runs a unique binary (atax 40 — not in the DMA-heavy
    // menu) so compile charges are attributed identically in both runs.
    use herov2::bench_harness::Variant;
    use herov2::sched::{BoardSpec, JobDesc, Placement, Policy, Priority, Scheduler};
    use herov2::workloads::synth;
    check(
        2,
        |rng| {
            (
                rng.usize(3, 5),
                rng.range(1, 1 << 20),
                rng.usize(1, 2),
                rng.bool(),
                rng.bool(),
                *rng.pick(&[0u64, 2]),
            )
        },
        |&(n, seed, pool, batching, pressure, headroom)| {
            let beat = aurora().dma_beat_bytes();
            let stream = synth::dma_heavy_jobs(n, seed);
            let probe = JobDesc {
                kernel: "atax",
                size: 40,
                variant: Variant::Handwritten,
                threads: 8,
                seed,
                arrival: 0,
                priority: Priority::Normal,
            };
            let placement =
                if pressure { Placement::Pressure } else { Placement::EarliestFree };
            let run = |priority: Priority| {
                let mut s = Scheduler::new(aurora(), pool, Policy::Fifo)
                    .with_placement(placement)
                    .with_board(
                        BoardSpec::with_bandwidth(beat).with_priority_headroom(headroom),
                    )
                    .with_batching(batching)
                    .with_verify(false);
                s.submit_all(&stream);
                let h = s.submit(JobDesc { priority, ..probe });
                s.drain().map_err(|e| e.to_string())?;
                let end = s
                    .poll(h)
                    .ok_or_else(|| "probe job did not complete".to_string())?
                    .end;
                Ok::<_, String>((end, s.report().digest))
            };
            let (high_end, high_digest) = run(Priority::High)?;
            let (normal_end, normal_digest) = run(Priority::Normal)?;
            if high_digest != normal_digest {
                return Err("priorities changed numerics".into());
            }
            if high_end > normal_end {
                return Err(format!(
                    "priority hurt its own job: turnaround {high_end} > {normal_end}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_chained_dataflow_matches_host_roundtrip() {
    // The dataflow acceptance bar: an A→B pipeline chained through
    // `.writes`/`.reads` buffer handles produces digests (and arrays)
    // bit-identical to the host-round-trip baseline — wait + read_f32 +
    // buffer_from_f32 between the stages — across pool sizes 1/2/4 and
    // both placement engines, plus the single-accelerator backend.
    use herov2::compiler::ir::{ci, ld, par_for, st, var, Kernel, KernelBuilder};
    use herov2::sched::{Placement, Policy, Scheduler};
    use herov2::Session;
    fn saxpy(n: i32) -> Kernel {
        let mut b = KernelBuilder::new("saxpy_chain_prop");
        let x = b.host_array("X", vec![ci(n)]);
        let y = b.host_array("Y", vec![ci(n)]);
        let a = b.float_param("a");
        let i = b.loop_var("i");
        b.body(vec![par_for(
            i,
            ci(0),
            ci(n),
            vec![st(y, vec![var(i)], var(a).mul(ld(x, vec![var(i)])).add(ld(y, vec![var(i)])))],
        )])
    }
    check(
        2,
        |rng| (rng.usize(16, 96), rng.range(1, 1 << 20)),
        |&(n, seed)| {
            let xs = workloads::gen_f32(seed, n);
            let ys = workloads::gen_f32(seed ^ 0xABC, n);
            let kernel = saxpy(n as i32);
            let e = |e: anyhow::Error| e.to_string();
            // Baseline: explicit host round-trip between the stages.
            let mut base = Session::single(aurora());
            let bx = base.buffer_from_f32(&xs);
            let by = base.buffer_from_f32(&ys);
            let la = base
                .launch(&kernel)
                .reads(&bx)
                .writes(&by)
                .fargs(&[3.0])
                .submit()
                .map_err(e)?;
            base.wait(&la).map_err(e)?;
            let mid = base.read_f32(&by).map_err(e)?; // read back to the host
            let bm = base.buffer_from_f32(&mid); // ... and re-upload
            let bz = base.buffer_zeroed(n);
            let lb = base
                .launch(&kernel)
                .reads(&bm)
                .writes(&bz)
                .fargs(&[0.25])
                .submit()
                .map_err(e)?;
            let baseline_digest = base.wait(&lb).map_err(e)?.digest;
            let baseline_out = base.read_f32(&bz).map_err(e)?;
            // Chained runs: B consumes A's pending output by handle.
            let chain = |mut sess: Session| -> Result<(u64, Vec<f32>), String> {
                let cx = sess.buffer_from_f32(&xs);
                let cy = sess.buffer_from_f32(&ys);
                let a =
                    sess.launch(&kernel).reads(&cx).writes(&cy).fargs(&[3.0]).submit().map_err(e)?;
                let cz = sess.buffer_zeroed(n);
                let b =
                    sess.launch(&kernel).reads(&cy).writes(&cz).fargs(&[0.25]).submit().map_err(e)?;
                let digest = sess.wait(&b).map_err(e)?.digest;
                sess.wait(&a).map_err(e)?;
                Ok((digest, sess.read_f32(&cz).map_err(e)?))
            };
            for pool in [1usize, 2, 4] {
                for placement in [Placement::EarliestFree, Placement::Pressure] {
                    let sched =
                        Scheduler::new(aurora(), pool, Policy::Fifo).with_placement(placement);
                    let (digest, out) = chain(Session::with_scheduler(sched))?;
                    if digest != baseline_digest {
                        return Err(format!(
                            "pool={pool} {placement:?}: chained digest {digest:#x} != \
                             baseline {baseline_digest:#x}"
                        ));
                    }
                    if out != baseline_out {
                        return Err(format!("pool={pool} {placement:?}: arrays diverged"));
                    }
                }
            }
            // The single-accelerator backend chains identically.
            let (digest, out) = chain(Session::single(aurora()))?;
            if digest != baseline_digest || out != baseline_out {
                return Err("single-backend chain diverged from the baseline".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_svm_offload_strategy_never_touches_numerics() {
    // The SVM offload strategy moves *cycles*, never data: the same
    // VA-described kernel stream served pinned, copied, or auto-selected
    // must produce bit-identical per-job results (hence an identical
    // report digest) — the pin/copy tradeoff is purely a timing question.
    use herov2::sched::{BoardSpec, Policy, Scheduler};
    use herov2::svm::{self, SvmConfig, SvmMode};
    check(
        2,
        |rng| (rng.usize(6, 14), rng.range(1, 1 << 20)),
        |&(n, seed)| {
            let mut digests = Vec::new();
            for over in [Some(SvmMode::Pin), Some(SvmMode::Copy), None] {
                let mut s = Scheduler::new(aurora(), 1, Policy::Fifo)
                    .with_board(BoardSpec::with_bandwidth(16))
                    .with_svm(SvmConfig::new(SvmMode::Auto).with_host_bw(8))
                    .with_verify(false);
                let handles =
                    svm::submit_svm_stream(&mut s, n, seed, over).map_err(|e| e.to_string())?;
                s.drain().map_err(|e| e.to_string())?;
                let r = s.report();
                if r.completed != n {
                    return Err(format!(
                        "{over:?}: only {} of {n} SVM jobs completed",
                        r.completed
                    ));
                }
                if handles.iter().any(|h| !s.state(*h).is_some_and(|st| st.settled())) {
                    return Err(format!("{over:?}: unsettled SVM handle"));
                }
                digests.push(r.digest);
            }
            if digests.windows(2).any(|w| w[0] != w[1]) {
                return Err(format!("digests diverge across SVM strategies: {digests:#x?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tlb_flush_policy_never_touches_numerics() {
    // `iommu.flush_on_offload` pins the old flush-every-offload driver
    // behavior; the default flushes only when the page table's epoch
    // advanced. Either way the TLB is a pure cost structure — job results
    // (and the golden-model checks) must be bit-identical.
    use herov2::sched::{Policy, Scheduler};
    use herov2::workloads::synth;
    check(
        2,
        |rng| (rng.usize(3, 6), rng.range(1, 1 << 20)),
        |&(n, seed)| {
            let jobs = synth::tiny_jobs(n, seed);
            let mut digests = Vec::new();
            for flush in [false, true] {
                let mut cfg = aurora();
                cfg.iommu.flush_on_offload = flush;
                let mut s = Scheduler::new(cfg, 2, Policy::Fifo);
                s.submit_all(&jobs);
                s.drain().map_err(|e| e.to_string())?;
                let r = s.report();
                if r.completed != jobs.len() {
                    return Err(format!(
                        "flush={flush}: only {} of {} jobs completed",
                        r.completed,
                        jobs.len()
                    ));
                }
                if r.verify_failures != 0 {
                    return Err(format!("flush={flush}: golden-model mismatch"));
                }
                digests.push(r.digest);
            }
            if digests[0] != digests[1] {
                return Err(format!(
                    "TLB flush policy changed numerics: {:#x} vs {:#x}",
                    digests[0], digests[1]
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_config_overrides_roundtrip() {
    check(
        40,
        |rng| {
            (
                *rng.pick(&[32u32, 64, 128]),
                *rng.pick(&[1usize, 2, 4, 8, 16]), // bank count must divide L1
                rng.usize(1, 64) * 1024,
                rng.bool(),
            )
        },
        |&(width, cores, tlb, xpulp)| {
            let text = format!(
                "preset = aurora\nnoc.dma_width_bits = {width}\n\
                 accel.cores_per_cluster = {cores}\niommu.tlb_entries = {tlb}\n\
                 accel.xpulp = {xpulp}\n"
            );
            let cfg = parse::parse_str(&text).map_err(|e| e)?;
            if cfg.noc.dma_width_bits != width
                || cfg.accel.cores_per_cluster != cores
                || cfg.iommu.tlb_entries != tlb
                || cfg.accel.isa.xpulp != xpulp
            {
                return Err("override not applied".into());
            }
            cfg.validate()
        },
    );
}

#[test]
fn prop_sched_selftuning_flags_off_is_bit_identical() {
    // The self-tuning machinery must be invisible until asked for:
    // `--lookahead 1` is the classic greedy dispatch by definition, and
    // `--preempt` on a stream with no High jobs never finds a displacer.
    // Both must reproduce the default scheduler's *full event sequence* —
    // not just the digest — on fuzzed streams.
    use herov2::sched::{Policy, Scheduler};
    use herov2::workloads::synth;
    check(
        2,
        |rng| (rng.usize(4, 7), rng.range(1, 1 << 20), rng.bool()),
        |&(n, seed, batch)| {
            let jobs = synth::tiny_jobs(n, seed);
            let run = |s: Scheduler| -> Result<Scheduler, String> {
                let mut s = s.with_batching(batch).with_verify(false);
                s.submit_all(&jobs);
                s.drain().map_err(|e| e.to_string())?;
                Ok(s)
            };
            for pool in [1usize, 2] {
                let mk = || Scheduler::new(aurora(), pool, Policy::Sjf);
                let base = run(mk())?;
                let greedy = run(mk().with_lookahead(1))?;
                if base.trace.events != greedy.trace.events {
                    return Err(format!("pool={pool}: lookahead=1 diverged from greedy"));
                }
                let pre = run(mk().with_preemption(true))?;
                if base.trace.events != pre.trace.events {
                    return Err(format!(
                        "pool={pool}: preemption displaced something in an all-Normal stream"
                    ));
                }
                let r = base.report();
                if r.completed != jobs.len() {
                    return Err(format!("pool={pool}: only {} completed", r.completed));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sched_selftuning_never_touches_numerics() {
    // Learning, lookahead and preemption all move *time*, never numerics:
    // a fuzzed stream with staggered arrivals and a High slice must
    // produce a bit-identical digest with every self-tuning feature on,
    // across pool sizes and both placement engines — and every job must
    // still complete.
    use herov2::sched::{Placement, Policy, Priority, Scheduler};
    use herov2::workloads::synth;
    check(
        2,
        |rng| (rng.usize(5, 8), rng.range(1, 1 << 20), rng.usize(2, 4)),
        |&(n, seed, hi_every)| {
            let jobs: Vec<synth::JobDesc> = synth::tiny_jobs(n, seed)
                .iter()
                .enumerate()
                .map(|(i, j)| {
                    let mut j = *j;
                    j.arrival = i as u64 * 40;
                    if i % hi_every == 1 {
                        j.priority = Priority::High;
                    }
                    j
                })
                .collect();
            let baseline = {
                let mut s = Scheduler::new(aurora(), 1, Policy::Fifo).with_verify(false);
                s.submit_all(&jobs);
                s.drain().map_err(|e| e.to_string())?;
                s.report().digest
            };
            for pool in [1usize, 2, 4] {
                for placement in [Placement::EarliestFree, Placement::Pressure] {
                    let mut s = Scheduler::new(aurora(), pool, Policy::Sjf)
                        .with_placement(placement)
                        .with_learning(true)
                        .with_lookahead(4)
                        .with_preemption(true)
                        .with_verify(false);
                    s.submit_all(&jobs);
                    s.drain().map_err(|e| e.to_string())?;
                    let r = s.report();
                    if r.completed != jobs.len() {
                        return Err(format!(
                            "pool={pool} {placement:?}: only {} of {} completed \
                             ({} preempted)",
                            r.completed,
                            jobs.len(),
                            r.preemptions
                        ));
                    }
                    if r.digest != baseline {
                        return Err(format!(
                            "pool={pool} {placement:?}: self-tuning changed numerics \
                             ({:#x} vs {baseline:#x})",
                            r.digest
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sched_autotune_off_is_bit_identical() {
    // The autotune machinery must be invisible until asked for, at event
    // granularity: an explicit `with_autotune(false)` is the default
    // construction, and `with_autotune(true)` on a stream with no AutoDMA
    // jobs never engages (the search only arms on autodma-compiled jobs).
    // Both must reproduce the untuned scheduler's *full event sequence* —
    // not just the digest — on fuzzed streams.
    use herov2::sched::{Policy, Scheduler};
    use herov2::workloads::synth;
    check(
        2,
        |rng| (rng.usize(4, 7), rng.range(1, 1 << 20), rng.bool()),
        |&(n, seed, batch)| {
            // Strip AutoDMA variants: this property is about the machinery
            // staying dormant, so the stream must give it nothing to arm on.
            let jobs: Vec<synth::JobDesc> = synth::tiny_jobs(n, seed)
                .iter()
                .map(|j| {
                    let mut j = *j;
                    if j.variant == Variant::AutoDma {
                        j.variant = Variant::Handwritten;
                    }
                    j
                })
                .collect();
            let run = |s: Scheduler| -> Result<Scheduler, String> {
                let mut s = s.with_batching(batch).with_verify(false);
                s.submit_all(&jobs);
                s.drain().map_err(|e| e.to_string())?;
                Ok(s)
            };
            for pool in [1usize, 2] {
                let mk = || Scheduler::new(aurora(), pool, Policy::Sjf);
                let base = run(mk())?;
                let off = run(mk().with_autotune(false))?;
                if base.trace.events != off.trace.events {
                    return Err(format!("pool={pool}: with_autotune(false) is not the default"));
                }
                let armed = run(mk().with_autotune(true))?;
                if base.trace.events != armed.trace.events {
                    return Err(format!(
                        "pool={pool}: autotune engaged on a stream with no AutoDMA jobs"
                    ));
                }
                let r = armed.report();
                if r.tune_searches != 0 || r.tune_hits != 0 {
                    return Err(format!(
                        "pool={pool}: {} search(es)/{} hit(s) without an autodma job",
                        r.tune_searches, r.tune_hits
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sched_autotune_never_touches_numerics() {
    // Tuning moves *time*, never numerics: a fuzzed AutoDMA job stream
    // must produce a bit-identical digest with schedule-time tuning on,
    // across pool sizes and both placement engines — every job completing
    // and the tuner actually searching (memoized: one search per distinct
    // (kernel, size) key, memo hits for the rest).
    use herov2::sched::{Placement, Policy, Scheduler};
    use herov2::workloads::synth;
    check(
        2,
        |rng| (rng.usize(4, 6), rng.range(1, 1 << 20)),
        |&(n, seed)| {
            let mut rng = Rng::new(seed);
            let jobs: Vec<synth::JobDesc> = (0..n)
                .map(|i| synth::JobDesc {
                    kernel: *rng.pick(&["gemm", "conv2d"]),
                    size: *rng.pick(&[24usize, 32]),
                    variant: Variant::AutoDma,
                    threads: 8,
                    seed: rng.next_u64(),
                    arrival: i as u64 * 30,
                    priority: herov2::sched::Priority::Normal,
                })
                .collect();
            let keys: std::collections::BTreeSet<(&str, usize)> =
                jobs.iter().map(|j| (j.kernel, j.size)).collect();
            let baseline = {
                let mut s = Scheduler::new(aurora(), 1, Policy::Fifo).with_verify(false);
                s.submit_all(&jobs);
                s.drain().map_err(|e| e.to_string())?;
                s.report().digest
            };
            for pool in [1usize, 2, 4] {
                for placement in [Placement::EarliestFree, Placement::Pressure] {
                    // Batching off so the search/hit count is exact: every
                    // job consults the TuneStore itself (a batch would share
                    // its head's lookup).
                    let mut s = Scheduler::new(aurora(), pool, Policy::Sjf)
                        .with_placement(placement)
                        .with_autotune(true)
                        .with_batching(false)
                        .with_verify(false);
                    s.submit_all(&jobs);
                    s.drain().map_err(|e| e.to_string())?;
                    let r = s.report();
                    if r.completed != jobs.len() {
                        return Err(format!(
                            "pool={pool} {placement:?}: only {} of {} completed",
                            r.completed,
                            jobs.len()
                        ));
                    }
                    if r.digest != baseline {
                        return Err(format!(
                            "pool={pool} {placement:?}: tuning changed numerics \
                             ({:#x} vs {baseline:#x})",
                            r.digest
                        ));
                    }
                    if r.tune_searches as usize != keys.len()
                        || (r.tune_searches + r.tune_hits) as usize != jobs.len()
                    {
                        return Err(format!(
                            "pool={pool} {placement:?}: {} search(es) + {} hit(s) for \
                             {} jobs over {} keys",
                            r.tune_searches,
                            r.tune_hits,
                            jobs.len(),
                            keys.len()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fleet_of_one_is_bit_identical_to_plain_scheduler() {
    // The fleet router's degenerate-identity guarantee: a fleet of one
    // board with the single default tenant is a zero-cost wrapper. The
    // board must see byte-identical submissions, so its *full event
    // sequence* — not just the digest — matches driving the scheduler
    // directly, on fuzzed streams, under both placement engines, with the
    // self-tuning features on and off, and under both routing policies
    // (with one board there is nothing to route between).
    use herov2::fleet::{RoutePolicy, Router};
    use herov2::sched::{Placement, Policy, Scheduler};
    use herov2::workloads::synth;
    check(
        2,
        |rng| (rng.usize(4, 7), rng.range(1, 1 << 20), rng.bool(), rng.bool()),
        |&(n, seed, learn, ahead)| {
            let jobs: Vec<synth::JobDesc> = synth::tiny_jobs(n, seed)
                .iter()
                .enumerate()
                .map(|(i, j)| {
                    let mut j = *j;
                    j.arrival = i as u64 * 25;
                    j
                })
                .collect();
            for placement in [Placement::EarliestFree, Placement::Pressure] {
                let mk = || {
                    Scheduler::new(aurora(), 2, Policy::Sjf)
                        .with_placement(placement)
                        .with_verify(false)
                        .with_learning(learn)
                        .with_lookahead(if ahead { 4 } else { 1 })
                };
                let mut solo = mk();
                solo.submit_all(&jobs);
                solo.drain().map_err(|e| e.to_string())?;
                let solo_report = solo.report();
                for route in [RoutePolicy::Finish, RoutePolicy::RoundRobin] {
                    let mut fleet = Router::new(vec![mk()]).with_route(route);
                    for j in &jobs {
                        fleet.submit(*j);
                    }
                    fleet.drain().map_err(|e| e.to_string())?;
                    if solo.trace.events != fleet.boards()[0].trace.events {
                        return Err(format!(
                            "{placement:?} learn={learn} ahead={ahead} {route:?}: \
                             fleet-of-1 event sequence diverged from the plain scheduler"
                        ));
                    }
                    let fr = fleet.report();
                    if fr.digest != solo_report.digest
                        || fr.makespan_cycles != solo_report.makespan_cycles
                        || fr.completed != solo_report.completed
                    {
                        return Err(format!(
                            "{placement:?} {route:?}: fleet-of-1 report diverged \
                             (digest {:#x} vs {:#x})",
                            fr.digest, solo_report.digest
                        ));
                    }
                    if fr.affinity_decisions != 0 {
                        return Err("a single-board fleet must never score routes".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sched_faults_off_is_bit_identical() {
    // The resilience machinery must be invisible until armed: an empty
    // fault plan, a retry budget with nothing to retry, and a zero-depth
    // retry-after queue reproduce the default *full event sequence* — not
    // just the digest — across pool sizes, placement engines and fleet
    // shapes.
    use herov2::fault::FaultPlan;
    use herov2::fleet::Router;
    use herov2::sched::{Placement, Policy, Scheduler};
    use herov2::workloads::synth;
    check(
        2,
        |rng| (rng.usize(4, 7), rng.range(1, 1 << 20)),
        |&(n, seed)| {
            let jobs = synth::tiny_jobs(n, seed);
            for placement in [Placement::EarliestFree, Placement::Pressure] {
                for pool in [1usize, 2, 4] {
                    let mk = || {
                        Scheduler::new(aurora(), pool, Policy::Sjf)
                            .with_placement(placement)
                            .with_verify(false)
                    };
                    let run = |mut s: Scheduler| -> Result<Scheduler, String> {
                        s.submit_all(&jobs);
                        s.drain().map_err(|e| e.to_string())?;
                        Ok(s)
                    };
                    let base = run(mk())?;
                    let armed = run(mk().with_faults(FaultPlan::default()).with_retry(3))?;
                    if base.trace.events != armed.trace.events {
                        return Err(format!(
                            "pool={pool} {placement:?}: an empty fault plan changed events"
                        ));
                    }
                    if base.report().digest != armed.report().digest {
                        return Err(format!("pool={pool} {placement:?}: digest diverged"));
                    }
                }
                // Fleet shapes: a resilience-armed router with no board
                // kills and a zero-depth retry-after queue must match the
                // plain router board-for-board.
                for boards in [1usize, 2] {
                    let mk_fleet = |armed: bool| -> Result<Router, String> {
                        let mk_board = || {
                            Scheduler::new(aurora(), 1, Policy::Sjf)
                                .with_placement(placement)
                                .with_verify(false)
                        };
                        let mut r = Router::new((0..boards).map(|_| mk_board()).collect());
                        if armed {
                            r = r.with_faults(&FaultPlan::default()).with_queue(0);
                        }
                        for j in &jobs {
                            r.submit(*j);
                        }
                        r.drain().map_err(|e| e.to_string())?;
                        Ok(r)
                    };
                    let plain = mk_fleet(false)?;
                    let armed = mk_fleet(true)?;
                    for b in 0..boards {
                        if plain.boards()[b].trace.events != armed.boards()[b].trace.events {
                            return Err(format!(
                                "{placement:?} fleet={boards}: board {b} events diverged"
                            ));
                        }
                    }
                    if plain.report().digest != armed.report().digest {
                        return Err(format!("{placement:?} fleet={boards}: digest diverged"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fault_retry_is_deterministic() {
    // Same plan, same stream ⇒ same fault schedule: the full event
    // sequence (faults, retries and all) and the digest are reproducible
    // run-to-run — the whole point of a seeded, counter-based fault model.
    use herov2::fault;
    use herov2::sched::{Policy, Scheduler};
    use herov2::workloads::synth;
    check(
        2,
        |rng| (rng.usize(5, 8), rng.range(1, 1 << 20), rng.range(1, 1 << 16)),
        |&(n, seed, fseed)| {
            let jobs = synth::tiny_jobs(n, seed);
            let plan = fault::parse(&format!("seed={fseed},transient=25,timeout=10"))?;
            let run = || -> Result<Scheduler, String> {
                let mut s = Scheduler::new(aurora(), 2, Policy::Sjf)
                    .with_verify(false)
                    .with_faults(plan.clone())
                    .with_retry(10);
                s.submit_all(&jobs);
                s.drain().map_err(|e| e.to_string())?;
                Ok(s)
            };
            let (a, b) = (run()?, run()?);
            if a.trace.events != b.trace.events {
                return Err("fault schedule not reproducible".into());
            }
            let (ra, rb) = (a.report(), b.report());
            if ra.digest != rb.digest || ra.retries != rb.retries {
                return Err(format!(
                    "report diverged: {:#x}/{} vs {:#x}/{}",
                    ra.digest, ra.retries, rb.digest, rb.retries
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_retried_faults_never_touch_numerics() {
    // A stream whose faults are all retried successfully must be
    // bit-identical to the fault-free run: a faulted attempt discards its
    // result before the digest, feed store, SVM write-back or learning
    // ever see it.
    use herov2::fault;
    use herov2::sched::{Policy, Scheduler};
    use herov2::workloads::synth;
    check(
        2,
        |rng| (rng.usize(5, 8), rng.range(1, 1 << 20), rng.range(1, 1 << 16)),
        |&(n, seed, fseed)| {
            let jobs = synth::tiny_jobs(n, seed);
            let plan = fault::parse(&format!("seed={fseed},transient=30"))?;
            // Premise: under the retry budget below every job must clear —
            // the draw is a pure function, so check it up front.
            for j in 0..jobs.len() as u64 {
                if !(0..=12).any(|a| plan.draw(j, a).is_none()) {
                    return Err(format!("premise: job {j} never clears under seed {fseed}"));
                }
            }
            let run = |plan: Option<fault::FaultPlan>| -> Result<Scheduler, String> {
                let mut s =
                    Scheduler::new(aurora(), 2, Policy::Sjf).with_verify(false).with_retry(12);
                if let Some(p) = plan {
                    s = s.with_faults(p);
                }
                s.submit_all(&jobs);
                s.drain().map_err(|e| e.to_string())?;
                Ok(s)
            };
            let clean = run(None)?.report();
            let faulted = run(Some(plan))?.report();
            if faulted.fault_failures != 0 {
                return Err(format!("{} permanent failure(s)", faulted.fault_failures));
            }
            if (clean.completed, faulted.completed) != (jobs.len(), jobs.len()) {
                return Err(format!(
                    "completed {} vs {} of {}",
                    clean.completed, faulted.completed, jobs.len()
                ));
            }
            if clean.digest != faulted.digest {
                return Err(format!(
                    "faults touched numerics: {:#x} vs {:#x}",
                    clean.digest, faulted.digest
                ));
            }
            Ok(())
        },
    );
}
