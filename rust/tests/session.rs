//! API-equivalence tests for the unified `Session` front door: the same
//! kernel must produce bit-identical outputs (and identical device cycles)
//! whether it goes through the legacy `omp::offload` path, a single
//! session, or a pooled session — the session layers move *plumbing*,
//! never numerics or time.

use herov2::accel::Accel;
use herov2::bench_harness::{self, verify_arrays, Variant};
use herov2::compiler::{compile, ir::*, LowerOpts};
use herov2::config::aurora;
use herov2::host::{HostBuf, HostContext};
use herov2::runtime::omp::offload;
use herov2::sched::{digest_arrays, BoardSpec, JobHandle, Policy, Scheduler};
use herov2::workloads::{self, gen_f32, synth};
use herov2::Session;

/// `y[i] = a*x[i] + y[i]` built with the public `KernelBuilder` — an
/// arbitrary kernel, not a `workloads::by_name` entry.
fn saxpy(n: i32) -> Kernel {
    let mut b = KernelBuilder::new("saxpy_equiv");
    let x = b.host_array("X", vec![ci(n)]);
    let y = b.host_array("Y", vec![ci(n)]);
    let a = b.float_param("a");
    let i = b.loop_var("i");
    b.body(vec![par_for(
        i,
        ci(0),
        ci(n),
        vec![st(y, vec![var(i)], var(a).mul(ld(x, vec![var(i)])).add(ld(y, vec![var(i)])))],
    )])
}

#[test]
fn session_single_matches_legacy_omp_offload() {
    let cfg = aurora();
    let n = 256usize;
    let xs = gen_f32(11, n);
    let ys = gen_f32(12, n);

    // Legacy path: compile by hand, thread `&mut Accel` through everything.
    let (lowered, _) = compile(&saxpy(n as i32), &LowerOpts::for_config(&cfg), None).unwrap();
    let mut accel = Accel::new(cfg.clone(), 1 << 20);
    let mut host = HostContext::new();
    let xb = host.alloc(&mut accel, n).unwrap();
    let yb = host.alloc(&mut accel, n).unwrap();
    host.write_f32(&mut accel, &xb, &xs);
    host.write_f32(&mut accel, &yb, &ys);
    let bufs: Vec<&HostBuf> = vec![&xb, &yb];
    let legacy = offload(&mut accel, &lowered, &bufs, &[3.0], 1, 100_000_000_000).unwrap();
    let legacy_arrays = vec![host.read_f32(&accel, &xb), host.read_f32(&accel, &yb)];
    let legacy_digest = digest_arrays(&legacy_arrays);

    // Session path: same kernel, same data, no plumbing.
    let mut sess = Session::single(cfg);
    let sx = sess.buffer_from_f32(&xs);
    let sy = sess.buffer_from_f32(&ys);
    let launch = sess.launch(&saxpy(n as i32)).args(&[&sx, &sy]).fargs(&[3.0]).submit().unwrap();
    let res = sess.wait(&launch).unwrap();

    assert_eq!(res.digest, legacy_digest, "outputs must be bit-identical");
    assert_eq!(res.device_cycles, legacy.device_cycles, "device cycles must be identical");
    assert_eq!(res.total_cycles, legacy.total_cycles);
    assert_eq!(sess.read_f32(&sy).unwrap(), legacy_arrays[1]);
}

#[test]
fn session_workload_matches_bench_harness() {
    let cfg = aurora();
    let seed = 21;
    for (w, variant) in [
        (workloads::gemm::build(16), Variant::Handwritten),
        (workloads::atax::build(24), Variant::AutoDma),
    ] {
        let legacy =
            bench_harness::run_workload(&cfg, &w, variant, 8, seed, 100_000_000_000).unwrap();
        let mut sess = Session::single(cfg.clone());
        let out = sess.run_workload(&w, variant, 8, seed).unwrap();
        verify_arrays(&w, &out.arrays, seed).unwrap();
        assert_eq!(
            digest_arrays(&out.arrays),
            digest_arrays(&legacy.arrays),
            "{} {}: session and harness outputs diverge",
            w.name,
            variant.label()
        );
        assert_eq!(out.result.device_cycles, legacy.result.device_cycles);
        assert_eq!(out.result.total_cycles, legacy.result.total_cycles);
    }
}

#[test]
fn arbitrary_kernel_pool_matches_single() {
    // The acceptance bar: a non-registry kernel submitted to a pooled
    // scheduler produces the same digest (and device cycles) as the
    // single-accelerator run of the same kernel.
    let n = 128usize;
    let xs = gen_f32(31, n);
    let ys = gen_f32(32, n);
    let run = |sess: &mut Session| {
        let sx = sess.buffer_from_f32(&xs);
        let sy = sess.buffer_from_f32(&ys);
        let launch =
            sess.launch(&saxpy(n as i32)).args(&[&sx, &sy]).fargs(&[0.5]).submit().unwrap();
        let res = sess.wait(&launch).unwrap();
        (res, sess.read_f32(&sy).unwrap())
    };
    let (single, single_y) = run(&mut Session::single(aurora()));
    let (pooled, pooled_y) = run(&mut Session::pool(aurora(), 3));
    assert_eq!(single.digest, pooled.digest);
    assert_eq!(single.device_cycles, pooled.device_cycles);
    assert_eq!(single_y, pooled_y);
    assert_eq!(pooled.instance, Some(0));
    // And the numerics are right (unfused mul+add on the device).
    for i in 0..n {
        assert_eq!(single_y[i], 0.5 * xs[i] + ys[i], "y[{i}]");
    }
}

#[test]
fn pool1_session_matches_uncontended_scheduler_baseline() {
    // A pooled session at pool=1 is the uncontended scheduler baseline:
    // same stream, same digest, same makespan, same device cycles.
    let jobs = synth::tiny_jobs(6, 17);

    let mut base = Scheduler::new(aurora(), 1, Policy::Fifo);
    base.submit_all(&jobs);
    base.drain().unwrap();
    let baseline = base.report();

    let sched =
        Scheduler::new(aurora(), 1, Policy::Fifo).with_board(BoardSpec::uncontended());
    let mut sess = Session::with_scheduler(sched);
    let handles = sess.submit_jobs(&jobs).unwrap();
    sess.drain().unwrap();
    let report = sess.report().unwrap();

    assert_eq!(report.digest, baseline.digest);
    assert_eq!(report.makespan_cycles, baseline.makespan_cycles);
    assert_eq!(report.total_device_cycles, baseline.total_device_cycles);
    assert_eq!(report.completed, jobs.len());
    for h in &handles {
        assert!(sess.job_state(*h).unwrap().settled());
    }
}

#[test]
fn pooled_kernel_launches_batch_and_cache() {
    // Two structurally identical custom kernels with different payloads:
    // one lowering, both complete, outputs independent.
    let mut sess = Session::pool(aurora(), 2);
    let n = 64usize;
    let mk = |sess: &mut Session, seed: u64| {
        let sx = sess.buffer_from_f32(&gen_f32(seed, n));
        let sy = sess.buffer_from_f32(&gen_f32(seed ^ 9, n));
        let launch = sess
            .launch(&saxpy(n as i32))
            .args(&[&sx, &sy])
            .fargs(&[2.0])
            .submit()
            .unwrap();
        (launch, sy)
    };
    let (l1, _y1) = mk(&mut sess, 1);
    let (l2, _y2) = mk(&mut sess, 2);
    let r1 = sess.wait(&l1).unwrap();
    let r2 = sess.wait(&l2).unwrap();
    assert_ne!(r1.digest, r2.digest, "different payloads, different outputs");
    let report = sess.report().unwrap();
    assert_eq!(report.completed, 2);
    assert_eq!(report.cache_misses, 1, "identical kernels share one lowered binary");
}

#[test]
fn serve_loop_resident_bytes_return_to_watermark() {
    // The PR 4 retention fix, extended to session buffers: a long pooled
    // serve-style loop that frees what it no longer needs must return the
    // session heap to its watermark after every free + drain — no
    // monotonic growth, even with chained (dataflow) launches in flight.
    let mut sess = Session::pool(aurora(), 2);
    let watermark = sess.resident_bytes();
    assert_eq!(watermark, 0);
    for round in 0..6u64 {
        let xs = gen_f32(round + 1, 128);
        let x = sess.buffer_from_f32(&xs);
        let y = sess.buffer_from_f32(&gen_f32(round + 77, 128));
        let a = sess
            .launch(&saxpy(128))
            .reads(&x)
            .writes(&y)
            .fargs(&[2.0])
            .submit()
            .unwrap();
        // Chained: stage B consumes A's pending output by handle.
        let z = sess.buffer_zeroed(128);
        let b = sess
            .launch(&saxpy(128))
            .reads(&y)
            .writes(&z)
            .fargs(&[0.5])
            .submit()
            .unwrap();
        sess.drain().unwrap();
        assert!(sess.poll(&a).is_some() && sess.poll(&b).is_some());
        let ys = sess.read_f32(&y).unwrap();
        let got = sess.read_f32(&z).unwrap();
        for i in 0..128 {
            assert_eq!(got[i], 0.5 * ys[i], "round {round}: z[{i}]");
        }
        sess.free(&x).unwrap();
        sess.free(&y).unwrap();
        sess.free(&z).unwrap();
        assert_eq!(sess.resident_bytes(), watermark, "round {round}: session heap grew");
    }
}

#[test]
fn freeing_chain_inputs_mid_flight_is_safe() {
    // An eagerly-snapshotted input buffer may be freed right after submit
    // (the launch owns its snapshot); the pending *output* may not.
    let mut sess = Session::pool(aurora(), 1);
    let xs = gen_f32(3, 64);
    let ys = gen_f32(4, 64);
    let x = sess.buffer_from_f32(&xs);
    let y = sess.buffer_from_f32(&ys);
    let l = sess.launch(&saxpy(64)).reads(&x).writes(&y).fargs(&[3.0]).submit().unwrap();
    sess.free(&x).unwrap();
    assert!(sess.free(&y).is_err(), "pending outputs must not be freed");
    let res = sess.wait(&l).unwrap();
    assert!(res.device_cycles > 0);
    let got = sess.read_f32(&y).unwrap();
    for i in 0..64 {
        assert_eq!(got[i], 3.0 * xs[i] + ys[i], "y[{i}]");
    }
    sess.free(&y).unwrap();
    assert_eq!(sess.resident_bytes(), 0);
}

#[test]
fn scheduler_handles_are_bounds_checked() {
    // Satellite regression: foreign/stale handles return None / error
    // instead of panicking.
    let mut s = Scheduler::new(aurora(), 1, Policy::Fifo);
    assert!(s.state(JobHandle(123)).is_none());
    assert!(s.poll(JobHandle(123)).is_none());
    assert!(s.wait(JobHandle(123)).is_err());
    let h = s.submit(synth::tiny_jobs(1, 1)[0]);
    s.drain().unwrap();
    assert!(s.state(h).unwrap().settled());
    assert!(s.poll(h).is_some());
}
