"""Layer 2: the kernel compute graphs, one per AOT artifact.

Each entry of `ARTIFACTS` is a jax function (calling the Layer-1 Pallas
kernels) plus its input shapes. Float parameters are baked as compile-time
constants matching the Rust workloads' `fargs`
(rust/src/workloads/*.rs::build) — the artifact names encode the problem
size, e.g. `gemm_128`.

Everything here runs only at build time (`make artifacts`); the Rust
runtime loads the lowered HLO text via PJRT.
"""

from .kernels import pallas_kernels as pk
from .kernels import ref

# Tap constants must match rust/src/workloads/conv2d.rs::TAPS.
TAPS = ((0.2, 0.5, -0.8), (-0.3, 0.6, -0.9), (0.4, 0.7, 0.10))

# fargs must match the Rust workload registry.
GEMM_ALPHA, GEMM_BETA = 1.5, 1.2
MM2_ALPHA = 1.5
MM3_ALPHA = 1.25
DARKNET_ALPHA = 1.0


def gemm_fn(a, b, c):
    return (pk.gemm(a, b, c, GEMM_ALPHA, GEMM_BETA),)


def mm2_fn(a, b):
    return (pk.matmul(a, b, alpha=MM2_ALPHA),)


def mm3_fn(a, b, c, d):
    e = pk.matmul(a, b, alpha=MM3_ALPHA)
    f = pk.matmul(c, d, alpha=MM3_ALPHA)
    g = pk.matmul(e, f, alpha=MM3_ALPHA)
    return (e, f, g)


def atax_fn(a, x):
    b = pk.matvec(a, x)
    y = pk.matvec(a.T, b)
    return (b, y)


def bicg_fn(a, p, r):
    q = pk.matvec(a, p)
    s = pk.matvec(a.T, r)
    return (q, s)


def conv2d_fn(a):
    return (pk.conv2d(a, TAPS),)


def covar_fn(d):
    n = d.shape[0]
    alpha = 1.0 / n
    d2, e, s = ref.covar(d, alpha)  # mean/subtract in jnp...
    # ...but the O(N^3) hot spot goes through the Pallas matmul.
    s = pk.matmul(d2.T, d2, alpha=1.0)
    return (d2, e, s)


def darknet_fn(a, b):
    return (pk.matmul(a, b, alpha=DARKNET_ALPHA),)


def _sq(n):
    return (n, n)


def artifacts(sizes=None):
    """name -> (fn, [input shapes]). `sizes` maps workload name -> N."""
    sz = {
        "gemm": 128,
        "mm2": 128,
        "mm3": 96,
        "atax": 512,
        "bicg": 512,
        "conv2d": 256,
        "covar": 128,
        "darknet": 192,
    }
    if sizes:
        sz.update(sizes)
    out = {}
    n = sz["gemm"]
    out[f"gemm_{n}"] = (gemm_fn, [_sq(n), _sq(n), _sq(n)])
    n = sz["mm2"]
    out[f"mm2_{n}"] = (mm2_fn, [_sq(n), _sq(n)])
    n = sz["mm3"]
    out[f"mm3_{n}"] = (mm3_fn, [_sq(n)] * 4)
    n = sz["atax"]
    out[f"atax_{n}"] = (atax_fn, [_sq(n), (n,)])
    n = sz["bicg"]
    out[f"bicg_{n}"] = (bicg_fn, [_sq(n), (n,), (n,)])
    n = sz["conv2d"]
    out[f"conv2d_{n}"] = (conv2d_fn, [_sq(n)])
    n = sz["covar"]
    out[f"covar_{n}"] = (covar_fn, [_sq(n)])
    n = sz["darknet"]
    out[f"darknet_{n}"] = (darknet_fn, [_sq(n), _sq(n)])
    return out
