"""AOT compilation: lower every Layer-2 kernel graph to HLO text.

HLO *text* — not `.serialize()` — is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids, which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot [--out-dir ../artifacts] [--tiny-only]

Python runs exactly once, here; the Rust binary only ever touches the
emitted `artifacts/*.hlo.txt`.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(name, fn, shapes, out_dir):
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"  {name}: {len(text)} chars -> {path}")
    return path


def smoke_fn(x, y):
    # The round-trip smoke artifact checked by the Rust test suite.
    return (jnp.matmul(x, y) + 2.0,)


# Tiny problem sizes matching rust/src/workloads/mod.rs::all_tiny().
TINY_SIZES = {
    "gemm": 12,
    "mm2": 12,
    "mm3": 10,
    "atax": 24,
    "bicg": 24,
    "conv2d": 18,
    "covar": 12,
    "darknet": 14,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--tiny-only", action="store_true",
                    help="emit only the tiny test-size artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    emit("smoke_matmul2", smoke_fn, [(2, 2), (2, 2)], args.out_dir)
    sets = [model.artifacts(TINY_SIZES)]
    if not args.tiny_only:
        sets.insert(0, model.artifacts())
    for arts in sets:
        for name, (fn, shapes) in arts.items():
            emit(name, fn, shapes, args.out_dir)
    # Stamp completeness so `make` can skip rebuilds.
    with open(os.path.join(args.out_dir, ".complete"), "w") as f:
        f.write("ok\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
