"""Pure-jnp reference implementations of the Table 2 kernels.

This is the correctness oracle for the Pallas kernels (Layer 1): pytest
checks every Pallas kernel against these with `assert_allclose`, and the
AOT artifacts (Layer 2) are built from functions that call the Pallas
kernels, so the whole chain is anchored here.

Float parameters (alpha/beta) are baked into the artifacts as compile-time
constants, mirroring the Rust workloads' `fargs` (see
rust/src/workloads/*.rs).
"""

import jax.numpy as jnp


def gemm(a, b, c, alpha, beta):
    """C = beta*C + alpha*A@B."""
    return beta * c + alpha * (a @ b)


def mm2(a, b, alpha):
    """2mm (Table 2): C = alpha*A@B."""
    return alpha * (a @ b)


def mm3(a, b, c, d, alpha):
    """3mm: E = alpha*A@B; F = alpha*C@D; G = alpha*E@F."""
    e = alpha * (a @ b)
    f = alpha * (c @ d)
    g = alpha * (e @ f)
    return e, f, g


def atax(a, x):
    """B = A@x; Y_i = sum_j A[j,i] * B[j] (A^T @ B)."""
    b = a @ x
    y = a.T @ b
    return b, y


def bicg(a, p, r):
    """Q = A@p; S_j = sum_i R_i A[i,j]."""
    q = a @ p
    s = r @ a
    return q, s


def conv2d(a, taps):
    """3x3 stencil over the valid region: B[i,j] = sum c[k,l] A[i+k,j+l]."""
    n = a.shape[0]
    m = n - 2
    out = jnp.zeros((m, m), dtype=a.dtype)
    for k in range(3):
        for l in range(3):
            out = out + taps[k][l] * a[k:k + m, l:l + m]
    return out


def covar(d, alpha):
    """E_j = alpha*sum_i D[i,j]; D -= E; S = D^T @ D (full square)."""
    e = alpha * jnp.sum(d, axis=0)
    d2 = d - e[None, :]
    s = d2.T @ d2
    return d2, e, s


def darknet(a, b, alpha):
    """One darknet conv layer as matmul: C = alpha*A@B."""
    return alpha * (a @ b)
