"""Layer-1 Pallas kernels.

The paper's accelerator is an SPM-based PULP cluster — architecturally much
closer to a TPU than to a GPU: the TCDM is a software-managed scratchpad
(VMEM), the cluster DMA engine overlaps HBM<->SPM transfers with compute
(Pallas's implicit grid pipelining), and the FPU MAC path is the compute
primitive (MXU). The kernels below therefore express the paper's tiling
directly as `BlockSpec`s:

* the matmul kernel tiles (M, N, K) into VMEM-resident blocks and
  accumulates over the K grid dimension — the Pallas analogue of the
  handwritten strip/2D tiling (tile side `S = floor((L/N)^(1/D))`, §3.1);
* the stencil kernel processes row blocks with a halo, like the
  handwritten conv2d strips;
* matvec kernels (atax/bicg) tile the row dimension.

All kernels run with `interpret=True`: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the interpret path is both the correctness path and
what the AOT artifacts embed (see /opt/xla-example/README.md). Real-TPU
performance is *estimated* from the block shapes in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block(n: int, pref: int) -> int:
    """Largest divisor of n that is <= pref (block sides must tile evenly)."""
    b = min(n, pref)
    while n % b != 0:
        b -= 1
    return b


# --- tiled matmul: out = alpha * x @ y (+ beta * c) -------------------------


def _matmul_kernel(x_ref, y_ref, o_ref, *, alpha, n_k):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += alpha * jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def matmul(x, y, alpha=1.0, bm=32, bn=32, bk=32):
    """alpha * x @ y with (bm, bn, bk) VMEM blocks."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    bm, bn, bk = _block(m, bm), _block(n, bn), _block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, alpha=alpha, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def gemm(a, b, c, alpha, beta):
    """C' = beta*C + alpha*A@B — Layer-2 entry calling the Layer-1 kernel."""
    return beta * c + matmul(a, b, alpha=alpha)


# --- tiled matvec: out = x @ v ----------------------------------------------


def _matvec_kernel(x_ref, v_ref, o_ref):
    o_ref[...] = x_ref[...] @ v_ref[...]


def matvec(x, v, bm=64):
    """x @ v with row blocks (the handwritten atax/bicg strip tiling)."""
    m, n = x.shape
    bm = _block(m, bm)
    return pl.pallas_call(
        _matvec_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(x, v)


# --- 3x3 stencil over row strips ---------------------------------------------


def _conv2d_kernel(a_ref, o_ref, *, taps, br, m):
    # The whole image stays visible; each grid step computes one `br`-row
    # strip, reading its strip + 2-row halo — the Pallas analogue of the
    # handwritten HERO strip (the strip, not the image, would live in VMEM
    # on a real TPU via a halo-aware BlockSpec).
    i = pl.program_id(0)
    a = a_ref[...]
    acc = jnp.zeros((br, m), dtype=jnp.float32)
    for k in range(3):
        for l in range(3):
            win = jax.lax.dynamic_slice(a, (i * br + k, l), (br, m))
            acc = acc + taps[k][l] * win
    o_ref[...] = acc


def conv2d(a, taps, br=32):
    """Valid 3x3 stencil; row strips of `br` output rows with 2-row halo,
    exactly like the handwritten HERO strips (workloads/conv2d.rs)."""
    n = a.shape[0]
    m = n - 2
    br = _block(m, br)
    return pl.pallas_call(
        functools.partial(_conv2d_kernel, taps=taps, br=br, m=m),
        grid=(m // br,),
        in_specs=[pl.BlockSpec((n, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=True,
    )(a)
