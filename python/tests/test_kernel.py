"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes; every kernel must match `ref.py` to fp32
tolerance. This is the build-time correctness gate of the three-layer
stack (the run-time gates are the Rust golden model and the PJRT-executed
artifacts).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pallas_kernels as pk
from compile.kernels import ref
from compile import model

RNG = np.random.default_rng(1234)


def rand(*shape):
    return RNG.uniform(-1.0, 1.0, size=shape).astype(np.float32)


dims = st.sampled_from([4, 8, 12, 16, 24, 32, 48, 64])


@settings(max_examples=12, deadline=None)
@given(m=dims, k=dims, n=dims, alpha=st.sampled_from([1.0, 1.5, -0.5]))
def test_matmul_matches_ref(m, k, n, alpha):
    x, y = rand(m, k), rand(k, n)
    got = pk.matmul(x, y, alpha=alpha)
    np.testing.assert_allclose(got, ref.mm2(x, y, alpha), rtol=2e-5, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(n=dims)
def test_gemm_matches_ref(n):
    a, b, c = rand(n, n), rand(n, n), rand(n, n)
    got = pk.gemm(a, b, c, 1.5, 1.2)
    np.testing.assert_allclose(got, ref.gemm(a, b, c, 1.5, 1.2), rtol=2e-5, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(m=dims, n=dims)
def test_matvec_matches_ref(m, n):
    x, v = rand(m, n), rand(n)
    np.testing.assert_allclose(pk.matvec(x, v), x @ v, rtol=2e-5, atol=1e-6)


@settings(max_examples=6, deadline=None)
@given(n=st.sampled_from([6, 10, 18, 34, 66]))
def test_conv2d_matches_ref(n):
    a = rand(n, n)
    got = pk.conv2d(a, model.TAPS)
    want = ref.conv2d(a, model.TAPS)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


def test_atax_composition():
    a, x = rand(24, 24), rand(24)
    b, y = model.atax_fn(a, x)
    rb, ry = ref.atax(a, x)
    np.testing.assert_allclose(b, rb, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(y, ry, rtol=2e-5, atol=1e-6)


def test_bicg_composition():
    a, p, r = rand(24, 24), rand(24), rand(24)
    q, s = model.bicg_fn(a, p, r)
    rq, rs = ref.bicg(a, p, r)
    np.testing.assert_allclose(q, rq, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(s, rs, rtol=2e-5, atol=1e-6)


def test_covar_composition():
    d = rand(12, 12)
    d2, e, s = model.covar_fn(d)
    rd2, re_, rs = ref.covar(d, 1.0 / 12)
    np.testing.assert_allclose(d2, rd2, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(e, re_, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(s, rs, rtol=1e-4, atol=1e-5)


def test_mm3_chains():
    n = 10
    a, b, c, d = rand(n, n), rand(n, n), rand(n, n), rand(n, n)
    e, f, g = model.mm3_fn(a, b, c, d)
    re_, rf, rg = ref.mm3(a, b, c, d, model.MM3_ALPHA)
    np.testing.assert_allclose(e, re_, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(f, rf, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(g, rg, rtol=1e-4, atol=1e-5)


def test_artifact_registry_shapes():
    arts = model.artifacts()
    assert len(arts) == 8
    for name, (fn, shapes) in arts.items():
        assert all(isinstance(s, tuple) for s in shapes), name


def test_block_divisor():
    assert pk._block(128, 32) == 32
    assert pk._block(97, 32) == 1  # prime: falls back to one block
    assert pk._block(12, 32) == 12


@pytest.mark.parametrize("n", [12, 16])
def test_matmul_odd_blocks(n):
    # Non-multiple-of-32 sizes exercise the divisor fallback.
    x, y = rand(n, n), rand(n, n)
    np.testing.assert_allclose(
        pk.matmul(x, y, alpha=2.0), 2.0 * (x @ y), rtol=2e-5, atol=1e-6
    )
