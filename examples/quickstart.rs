//! Quickstart: offload one kernel through the full HEROv2 stack.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Walks the whole platform once: build the Aurora configuration, compile
//! the gemm OpenMP kernel with the heterogeneous compiler, allocate shared
//! buffers in the host process, offload, and verify the simulated
//! accelerator's numerics against (a) the host golden model and (b) the
//! AOT-compiled JAX/Pallas artifact executed via PJRT.

use herov2::bench_harness::{run_workload, verify, verify_pjrt, Variant};
use herov2::config::aurora;
use herov2::runtime::pjrt::PjrtRuntime;
use herov2::workloads;

fn main() -> anyhow::Result<()> {
    let cfg = aurora();
    println!("platform: {} ({} x {} cores, {} KiB L1 TCDM, {} MHz)",
        cfg.name,
        cfg.accel.n_clusters,
        cfg.accel.cores_per_cluster,
        cfg.accel.l1_bytes / 1024,
        cfg.accel.freq_mhz);

    let w = workloads::gemm::build(128); // matches the gemm_128 AOT artifact
    println!("kernel: {} N={} ({} map-clause arrays)", w.name, w.size, w.arrays.len());

    let seed = 1;
    for variant in [Variant::Unmodified, Variant::AutoDma, Variant::Handwritten] {
        let out = run_workload(&cfg, &w, variant, 8, seed, 10_000_000_000)?;
        verify(&w, &out, seed)?;
        println!(
            "{:<12}: {:>9} device cycles ({:>6.2} ms wall at {} MHz), numerics OK",
            variant.label(),
            out.cycles(),
            out.cycles() as f64 / (cfg.accel.freq_mhz as f64 * 1e3),
            cfg.accel.freq_mhz
        );
    }

    // Three-layer check: simulated RV32 accelerator vs XLA-executed HLO.
    let out = run_workload(&cfg, &w, Variant::Handwritten, 8, seed, 10_000_000_000)?;
    match PjrtRuntime::new(PjrtRuntime::default_dir()) {
        Ok(mut rt) => match verify_pjrt(&mut rt, &w, &out, seed)? {
            true => println!("PJRT (JAX/Pallas artifact {}) check: OK", w.pjrt.name),
            false => println!("PJRT artifact not built — run `make artifacts` first"),
        },
        Err(e) => println!("PJRT unavailable in this environment: {e}"),
    }
    Ok(())
}
