//! Quickstart: offload one kernel through the full HEROv2 stack.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Walks the whole platform once through the unified `Session` front door:
//! build the Aurora configuration, open a single-accelerator session,
//! launch the gemm workload in three compilation variants, and verify the
//! simulated accelerator's numerics against (a) the host golden model and
//! (b) the AOT-compiled JAX/Pallas artifact executed via PJRT. No
//! `&mut Accel` or raw `HostBuf` plumbing appears anywhere — the session
//! owns the device.

use herov2::bench_harness::{verify_arrays, verify_pjrt_arrays, Variant};
use herov2::config::aurora;
use herov2::runtime::pjrt::PjrtRuntime;
use herov2::workloads;
use herov2::Session;

fn main() -> anyhow::Result<()> {
    let cfg = aurora();
    println!("platform: {} ({} x {} cores, {} KiB L1 TCDM, {} MHz)",
        cfg.name,
        cfg.accel.n_clusters,
        cfg.accel.cores_per_cluster,
        cfg.accel.l1_bytes / 1024,
        cfg.accel.freq_mhz);

    let w = workloads::gemm::build(128); // matches the gemm_128 AOT artifact
    println!("kernel: {} N={} ({} map-clause arrays)", w.name, w.size, w.arrays.len());

    let mut sess = Session::single(cfg.clone());
    let seed = 1;
    for variant in [Variant::Unmodified, Variant::AutoDma, Variant::Handwritten] {
        let out = sess.run_workload(&w, variant, 8, seed)?;
        verify_arrays(&w, &out.arrays, seed)?;
        println!(
            "{:<12}: {:>9} device cycles ({:>6.2} ms wall at {} MHz), numerics OK",
            variant.label(),
            out.result.device_cycles,
            out.result.device_cycles as f64 / (cfg.accel.freq_mhz as f64 * 1e3),
            cfg.accel.freq_mhz
        );
    }

    // Three-layer check: simulated RV32 accelerator vs XLA-executed HLO.
    let out = sess.run_workload(&w, Variant::Handwritten, 8, seed)?;
    match PjrtRuntime::new(PjrtRuntime::default_dir()) {
        Ok(mut rt) => match verify_pjrt_arrays(&mut rt, &w, &out.arrays, seed)? {
            true => println!("PJRT (JAX/Pallas artifact {}) check: OK", w.pjrt.name),
            false => println!("PJRT artifact not built — run `make artifacts` first"),
        },
        Err(e) => println!("PJRT unavailable in this environment: {e}"),
    }
    Ok(())
}
