//! End-to-end driver: darknet-style CNN inference through the full stack.
//!
//! ```sh
//! cargo run --release --example darknet_e2e
//! ```
//!
//! The paper's `darknet` application runs YOLO object detection with every
//! convolutional layer lowered to a matrix-matrix multiplication and
//! offloaded to the accelerator (§3, Table 2). This driver reproduces that
//! structure on a tiny YOLO-style network:
//!
//!   image 32x32x3 → conv3x3(16) + ReLU → conv3x3(16→32) + ReLU
//!                 → global average pool → linear(10)
//!
//! Each conv layer is im2col'd on the host (as darknet does) and its GEMM
//! is built as a *custom rectangular kernel* with the public `KernelBuilder`
//! API — not a registry workload — then launched through the unified
//! `Session` front door (AutoDMA tiling, zero manual DMA code).
//!
//! The stages form a **device-resident pipeline**: every GEMM `.writes`
//! its output buffer and the ReLU that follows chains on it in place
//! (`.writes` of the pending buffer), so the activation never round-trips
//! to the host between the two stages; the classifier goes further —
//! GEMM → ReLU → global-average-pool GEMM → linear GEMM is one four-stage
//! chain, with the pooled vector flowing producer-to-consumer entirely by
//! buffer handle. Only the im2col between conv layers touches the host,
//! exactly like the paper's application split. Input buffers are freed as
//! layers finish, so the session heap stays at its watermark.
//!
//! Every layer is verified against a host golden model; the run reports
//! per-layer cycles and the end-to-end speedup of AutoDMA offloading vs
//! running the same kernels on external memory — the paper's headline
//! metric for this application. Two final checks pin the dataflow
//! redesign's acceptance bar: a chained GEMM→ReLU pipeline is bit-identical
//! to the same launches with a host round-trip (read_f32 +
//! buffer_from_f32) between them, and the same custom GEMM on a *pooled*
//! session (2 accelerator instances behind the offload scheduler) is
//! bit-identical to the single-accelerator launch: one API, any number of
//! devices.

use anyhow::Result;
use herov2::bench_harness::geomean;
use herov2::compiler::ir::*;
use herov2::config::aurora;
use herov2::workloads::gen_f32;
use herov2::Session;

/// Build `C[M][N] = A[M][K] @ B[K][N]` as an unmodified OpenMP kernel; the
/// AutoDMA pass does the tiling.
fn mm_kernel(m: i32, kk: i32, n: i32) -> Kernel {
    let mut b = KernelBuilder::new("conv_as_gemm");
    let a = b.host_array("A", vec![ci(m), ci(kk)]);
    let bb = b.host_array("B", vec![ci(kk), ci(n)]);
    let c = b.host_array("C", vec![ci(m), ci(n)]);
    let (i, j, k) = (b.loop_var("i"), b.loop_var("j"), b.loop_var("k"));
    b.body(vec![Stmt::For {
        var: i,
        lo: ci(0),
        hi: ci(m),
        par: Par::Cores,
        body: vec![for_(
            j,
            ci(0),
            ci(n),
            vec![
                st(c, vec![var(i), var(j)], cf(0.0)),
                for_(
                    k,
                    ci(0),
                    ci(kk),
                    vec![st(
                        c,
                        vec![var(i), var(j)],
                        ld(c, vec![var(i), var(j)]).add(
                            ld(a, vec![var(i), var(k)]).mul(ld(bb, vec![var(k), var(j)])),
                        ),
                    )],
                ),
            ],
        )],
    }])
}

/// Elementwise in-place ReLU: `X[i] = max(X[i], 0)` — the chained stage
/// that keeps conv outputs device-resident.
fn relu_kernel(n: i32) -> Kernel {
    let mut b = KernelBuilder::new("relu_inplace");
    let x = b.host_array("X", vec![ci(n)]);
    let i = b.loop_var("i");
    b.body(vec![par_for(
        i,
        ci(0),
        ci(n),
        vec![st(x, vec![var(i)], ld(x, vec![var(i)]).max(cf(0.0)))],
    )])
}

/// im2col for 3x3 valid convolution: (C_in*9) x (H-2)*(W-2).
fn im2col(input: &[f32], c_in: usize, h: usize, w: usize) -> (Vec<f32>, usize, usize) {
    let (oh, ow) = (h - 2, w - 2);
    let cols = oh * ow;
    let rows = c_in * 9;
    let mut out = vec![0.0; rows * cols];
    for c in 0..c_in {
        for ky in 0..3 {
            for kx in 0..3 {
                let r = c * 9 + ky * 3 + kx;
                for y in 0..oh {
                    for x in 0..ow {
                        out[r * cols + y * ow + x] =
                            input[c * h * w + (y + ky) * w + (x + kx)];
                    }
                }
            }
        }
    }
    (out, rows, cols)
}

fn golden_mm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a[i * k + kk] * b[kk * n + j];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

fn allclose(name: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{name}: length mismatch");
    for (g, w) in got.iter().zip(want) {
        assert!((g - w).abs() <= 1e-4 + 1e-4 * w.abs(), "{name} mismatch: {g} vs {w}");
    }
}

fn run_network(autodma: bool) -> Result<(Vec<f32>, Vec<(String, u64)>)> {
    let mut sess = Session::single(aurora());
    let (mut h, mut w) = (32usize, 32usize);
    // Synthetic 32x32 RGB image + deterministic weights.
    let img: Vec<f32> = gen_f32(7, 3 * h * w);
    let mut log = Vec::new();
    let watermark = sess.resident_bytes();

    // --- conv1: GEMM → ReLU chained on the device, then read back once
    // for the host im2col between the layers (the only host step, exactly
    // like darknet's application split).
    let (cols1, kr1, nc1) = im2col(&img, 3, h, w);
    let w1 = gen_f32(100, 16 * kr1);
    let w1b = sess.buffer_from_f32(&w1);
    let c1b = sess.buffer_from_f32(&cols1);
    let o1b = sess.buffer_zeroed(16 * nc1);
    let g1 = sess
        .launch(&mm_kernel(16, kr1 as i32, nc1 as i32))
        .reads(&w1b)
        .reads(&c1b)
        .writes(&o1b)
        .autodma(autodma)
        .submit()?;
    let r1 = sess.launch(&relu_kernel((16 * nc1) as i32)).writes(&o1b).submit()?;
    // Waiting the chain tail resolves the GEMM first; its result is
    // memoized, so reading its cycles afterwards costs nothing.
    sess.wait(&r1)?;
    let cyc1 = sess.wait(&g1)?.device_cycles;
    let act1 = sess.read_f32(&o1b)?;
    let want1: Vec<f32> =
        golden_mm(16, kr1, nc1, &w1, &cols1).into_iter().map(|v| v.max(0.0)).collect();
    allclose("conv1", &act1, &want1);
    sess.free(&w1b)?;
    sess.free(&c1b)?;
    sess.free(&o1b)?;
    h -= 2;
    w -= 2;
    log.push((format!("conv1 (16x{h}x{w})"), cyc1));
    assert_eq!(sess.resident_bytes(), watermark, "freed conv1 buffers must not leak");

    // --- conv2 → ReLU → global-average-pool → linear: one FOUR-stage
    // device-resident chain. The conv output, its activation and the
    // pooled vector flow launch-to-launch by buffer handle only — zero
    // host copies inside the chain, resolved by a single wait at the tail.
    let (cols2, kr2, nc2) = im2col(&act1, 16, h, w);
    let w2 = gen_f32(101, 32 * kr2);
    let w2b = sess.buffer_from_f32(&w2);
    let c2b = sess.buffer_from_f32(&cols2);
    let o2b = sess.buffer_zeroed(32 * nc2);
    let g2 = sess
        .launch(&mm_kernel(32, kr2 as i32, nc2 as i32))
        .reads(&w2b)
        .reads(&c2b)
        .writes(&o2b)
        .autodma(autodma)
        .submit()?;
    let r2 = sess.launch(&relu_kernel((32 * nc2) as i32)).writes(&o2b).submit()?;
    h -= 2;
    w -= 2;
    let hw = h * w;
    assert_eq!(nc2, hw, "conv2's output columns are exactly the pooling matrix");
    let u = vec![1.0 / hw as f32; hw];
    let ub = sess.buffer_from_f32(&u);
    let pb = sess.buffer_zeroed(32);
    let pool = sess
        .launch(&mm_kernel(32, hw as i32, 1))
        .reads(&o2b) // chained: conv2's ReLU output, still pending
        .reads(&ub)
        .writes(&pb)
        .submit()?;
    let wfc = gen_f32(999, 10 * 32);
    let fb = sess.buffer_from_f32(&wfc);
    let lb = sess.buffer_zeroed(10);
    let lin = sess
        .launch(&mm_kernel(10, 32, 1))
        .reads(&fb)
        .reads(&pb) // chained: the pooled vector, still pending
        .writes(&lb)
        .submit()?;
    // One wait resolves the whole four-stage chain.
    sess.wait(&lin)?;
    let cyc2 = sess.wait(&g2)?.device_cycles;
    assert!(sess.poll(&r2).is_some() && sess.poll(&pool).is_some());
    log.push((format!("conv2 (32x{h}x{w})"), cyc2));

    // Verify every stage against the host golden model.
    let act2 = sess.read_f32(&o2b)?;
    let want2: Vec<f32> =
        golden_mm(32, kr2, nc2, &w2, &cols2).into_iter().map(|v| v.max(0.0)).collect();
    allclose("conv2", &act2, &want2);
    let pooled = sess.read_f32(&pb)?;
    let pooled_want: Vec<f32> =
        (0..32).map(|c| golden_mm(1, hw, 1, &act2[c * hw..(c + 1) * hw], &u)[0]).collect();
    allclose("avgpool", &pooled, &pooled_want);
    let logits = sess.read_f32(&lb)?;
    allclose("linear", &logits, &golden_mm(10, 32, 1, &wfc, &pooled));

    // Free the lot: the session heap must return to its watermark.
    for b in [&w2b, &c2b, &o2b, &ub, &pb, &fb, &lb] {
        sess.free(b)?;
    }
    assert_eq!(sess.resident_bytes(), watermark, "freed pipeline must not leak");
    Ok((logits, log))
}

/// The same custom GEMM, single vs pooled: digests must be bit-identical.
fn pool_digest_check() -> Result<()> {
    let (m, k, n) = (16usize, 27, 64);
    let a = gen_f32(41, m * k);
    let b = gen_f32(42, k * n);
    let run = |sess: &mut Session| -> Result<u64> {
        let ab = sess.buffer_from_f32(&a);
        let bb = sess.buffer_from_f32(&b);
        let cb = sess.buffer_zeroed(m * n);
        let kernel = mm_kernel(m as i32, k as i32, n as i32);
        let launch = sess.launch(&kernel).args(&[&ab, &bb, &cb]).autodma(true).submit()?;
        Ok(sess.wait(&launch)?.digest)
    };
    let single = run(&mut Session::single(aurora()))?;
    let pooled = run(&mut Session::pool(aurora(), 2))?;
    assert_eq!(single, pooled, "pooled launch must be bit-identical to single");
    println!(
        "\ncustom GEMM through a pool=2 session: digest {pooled:#018x} — \
         bit-identical to the single-accelerator launch"
    );
    Ok(())
}

/// The dataflow acceptance bar: GEMM→ReLU chained by buffer handle must be
/// bit-identical to the same two launches with a host round-trip
/// (`read_f32` + `buffer_from_f32`) between them — single and pooled.
fn resident_vs_roundtrip_check() -> Result<()> {
    let (m, k, n) = (16usize, 27, 64);
    let a = gen_f32(41, m * k);
    let b = gen_f32(42, k * n);
    let chained = |sess: &mut Session| -> Result<(u64, Vec<f32>)> {
        let ab = sess.buffer_from_f32(&a);
        let bb = sess.buffer_from_f32(&b);
        let cb = sess.buffer_zeroed(m * n);
        let g = sess
            .launch(&mm_kernel(m as i32, k as i32, n as i32))
            .reads(&ab)
            .reads(&bb)
            .writes(&cb)
            .autodma(true)
            .submit()?;
        let r = sess.launch(&relu_kernel((m * n) as i32)).writes(&cb).submit()?;
        let digest = sess.wait(&r)?.digest;
        sess.wait(&g)?;
        Ok((digest, sess.read_f32(&cb)?))
    };
    let roundtrip = |sess: &mut Session| -> Result<(u64, Vec<f32>)> {
        let ab = sess.buffer_from_f32(&a);
        let bb = sess.buffer_from_f32(&b);
        let cb = sess.buffer_zeroed(m * n);
        let g = sess
            .launch(&mm_kernel(m as i32, k as i32, n as i32))
            .reads(&ab)
            .reads(&bb)
            .writes(&cb)
            .autodma(true)
            .submit()?;
        sess.wait(&g)?;
        let host_copy = sess.read_f32(&cb)?; // explicit host round-trip
        let cb2 = sess.buffer_from_f32(&host_copy); // ... and re-upload
        let r = sess.launch(&relu_kernel((m * n) as i32)).writes(&cb2).submit()?;
        let digest = sess.wait(&r)?.digest;
        Ok((digest, sess.read_f32(&cb2)?))
    };
    let (d_chain, o_chain) = chained(&mut Session::single(aurora()))?;
    let (d_rt, o_rt) = roundtrip(&mut Session::single(aurora()))?;
    assert_eq!(d_chain, d_rt, "chained digest must equal the host-round-trip digest");
    assert_eq!(o_chain, o_rt);
    let (d_pool, o_pool) = chained(&mut Session::pool(aurora(), 2))?;
    assert_eq!(d_chain, d_pool, "the pooled chain must be bit-identical too");
    assert_eq!(o_chain, o_pool);
    println!(
        "GEMM→ReLU chained by handle: digest {d_chain:#018x} — bit-identical to the \
         host-round-trip baseline (single and pool=2)"
    );
    Ok(())
}

fn main() -> Result<()> {
    println!("darknet_e2e — tiny YOLO-style CNN, conv layers offloaded as GEMMs");
    println!("(GEMM→ReLU device-resident per layer; classifier is a 4-stage device chain)\n");
    let (logits_auto, log_auto) = run_network(true)?;
    let (logits_remote, log_remote) = run_network(false)?;
    // Both paths must agree bit-for-bit (same kernels, different memories).
    assert_eq!(logits_auto, logits_remote, "offload paths disagree");

    let freq = aurora().accel.freq_mhz as f64;
    println!("{:<22} {:>14} {:>14} {:>9}", "layer", "autodma (cy)", "remote (cy)", "speedup");
    let mut speedups = Vec::new();
    let (mut tot_a, mut tot_r) = (0u64, 0u64);
    for ((name, ca), (_, cr)) in log_auto.iter().zip(&log_remote) {
        println!("{:<22} {:>14} {:>14} {:>8.2}x", name, ca, cr, *cr as f64 / *ca as f64);
        speedups.push(*cr as f64 / *ca as f64);
        tot_a += ca;
        tot_r += cr;
    }
    println!(
        "\nend-to-end conv time: {:.2} ms (AutoDMA) vs {:.2} ms (external memory) \
         at {freq} MHz — {:.2}x, geomean {:.2}x",
        tot_a as f64 / (freq * 1e3),
        tot_r as f64 / (freq * 1e3),
        tot_r as f64 / tot_a as f64,
        geomean(&speedups)
    );
    println!("logits: {:?}", &logits_auto[..5.min(logits_auto.len())]);
    println!("all layers verified against the host golden model: OK");

    pool_digest_check()?;
    resident_vs_roundtrip_check()?;
    Ok(())
}
