//! End-to-end driver: darknet-style CNN inference through the full stack.
//!
//! ```sh
//! cargo run --release --example darknet_e2e
//! ```
//!
//! The paper's `darknet` application runs YOLO object detection with every
//! convolutional layer lowered to a matrix-matrix multiplication and
//! offloaded to the accelerator (§3, Table 2). This driver reproduces that
//! structure on a tiny YOLO-style network:
//!
//!   image 32x32x3 → conv3x3(16) + ReLU → conv3x3(16→32) + ReLU
//!                 → global average pool → linear(10)
//!
//! Each conv layer is im2col'd on the host (as darknet does) and its GEMM
//! is built as a *custom rectangular kernel* with the public `KernelBuilder`
//! API — not a registry workload — then launched through the unified
//! `Session` front door (AutoDMA tiling, zero manual DMA code). Host work
//! (im2col, ReLU, pooling) stays on the host, exactly like the paper's
//! application split. Every layer is verified against a host golden model;
//! the run reports per-layer cycles and the end-to-end speedup of AutoDMA
//! offloading vs running the same kernels on external memory — the paper's
//! headline metric for this application. A final section submits the same
//! custom GEMM to a *pooled* session (2 accelerator instances behind the
//! offload scheduler) and checks the digest is bit-identical to the
//! single-accelerator launch: one API, any number of devices.

use anyhow::Result;
use herov2::bench_harness::geomean;
use herov2::compiler::ir::*;
use herov2::config::aurora;
use herov2::workloads::gen_f32;
use herov2::Session;

/// Build `C[M][N] = A[M][K] @ B[K][N]` as an unmodified OpenMP kernel; the
/// AutoDMA pass does the tiling.
fn mm_kernel(m: i32, kk: i32, n: i32) -> Kernel {
    let mut b = KernelBuilder::new("conv_as_gemm");
    let a = b.host_array("A", vec![ci(m), ci(kk)]);
    let bb = b.host_array("B", vec![ci(kk), ci(n)]);
    let c = b.host_array("C", vec![ci(m), ci(n)]);
    let (i, j, k) = (b.loop_var("i"), b.loop_var("j"), b.loop_var("k"));
    b.body(vec![Stmt::For {
        var: i,
        lo: ci(0),
        hi: ci(m),
        par: Par::Cores,
        body: vec![for_(
            j,
            ci(0),
            ci(n),
            vec![
                st(c, vec![var(i), var(j)], cf(0.0)),
                for_(
                    k,
                    ci(0),
                    ci(kk),
                    vec![st(
                        c,
                        vec![var(i), var(j)],
                        ld(c, vec![var(i), var(j)]).add(
                            ld(a, vec![var(i), var(k)]).mul(ld(bb, vec![var(k), var(j)])),
                        ),
                    )],
                ),
            ],
        )],
    }])
}

/// im2col for 3x3 valid convolution: (C_in*9) x (H-2)*(W-2).
fn im2col(input: &[f32], c_in: usize, h: usize, w: usize) -> (Vec<f32>, usize, usize) {
    let (oh, ow) = (h - 2, w - 2);
    let cols = oh * ow;
    let rows = c_in * 9;
    let mut out = vec![0.0; rows * cols];
    for c in 0..c_in {
        for ky in 0..3 {
            for kx in 0..3 {
                let r = c * 9 + ky * 3 + kx;
                for y in 0..oh {
                    for x in 0..ow {
                        out[r * cols + y * ow + x] =
                            input[c * h * w + (y + ky) * w + (x + kx)];
                    }
                }
            }
        }
    }
    (out, rows, cols)
}

struct Layer {
    name: &'static str,
    c_out: usize,
}

fn golden_mm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a[i * k + kk] * b[kk * n + j];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

/// Launch one im2col'd conv GEMM through the session; returns C + cycles.
fn offload_mm(
    sess: &mut Session,
    autodma: bool,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
) -> Result<(Vec<f32>, u64)> {
    let kernel = mm_kernel(m as i32, k as i32, n as i32);
    let ab = sess.buffer_from_f32(a);
    let bb = sess.buffer_from_f32(b);
    let cb = sess.buffer_zeroed(m * n);
    let launch =
        sess.launch(&kernel).args(&[&ab, &bb, &cb]).autodma(autodma).submit()?;
    let res = sess.wait(&launch)?;
    Ok((sess.read_f32(&cb)?, res.device_cycles))
}

fn run_network(autodma: bool) -> Result<(Vec<f32>, Vec<(String, u64)>)> {
    let mut sess = Session::single(aurora());

    // Synthetic 32x32 RGB image + deterministic weights.
    let (mut h, mut w, mut c_in) = (32usize, 32usize, 3usize);
    let mut act: Vec<f32> = gen_f32(7, c_in * h * w);
    let layers = [Layer { name: "conv1", c_out: 16 }, Layer { name: "conv2", c_out: 32 }];
    let mut log = Vec::new();
    for (li, layer) in layers.iter().enumerate() {
        let (cols_mat, krows, cols) = im2col(&act, c_in, h, w);
        let weights = gen_f32(100 + li as u64, layer.c_out * krows);
        let (out, cycles) =
            offload_mm(&mut sess, autodma, layer.c_out, krows, cols, &weights, &cols_mat)?;
        // Verify the offloaded GEMM against the host golden model.
        let want = golden_mm(layer.c_out, krows, cols, &weights, &cols_mat);
        for (g, wv) in out.iter().zip(&want) {
            assert!((g - wv).abs() <= 1e-4 + 1e-4 * wv.abs(), "{} mismatch", layer.name);
        }
        // ReLU on the host (as darknet does between offloads).
        act = out.iter().map(|v| v.max(0.0)).collect();
        h -= 2;
        w -= 2;
        c_in = layer.c_out;
        log.push((format!("{} ({}x{}x{})", layer.name, layer.c_out, h, w), cycles));
    }
    // Global average pool + linear classifier (host side).
    let hw = h * w;
    let pooled: Vec<f32> =
        (0..c_in).map(|c| act[c * hw..(c + 1) * hw].iter().sum::<f32>() / hw as f32).collect();
    let wfc = gen_f32(999, 10 * c_in);
    let logits: Vec<f32> = (0..10)
        .map(|o| (0..c_in).map(|c| wfc[o * c_in + c] * pooled[c]).sum())
        .collect();
    Ok((logits, log))
}

/// The same custom GEMM, single vs pooled: digests must be bit-identical.
fn pool_digest_check() -> Result<()> {
    let (m, k, n) = (16usize, 27, 64);
    let a = gen_f32(41, m * k);
    let b = gen_f32(42, k * n);
    let run = |sess: &mut Session| -> Result<u64> {
        let ab = sess.buffer_from_f32(&a);
        let bb = sess.buffer_from_f32(&b);
        let cb = sess.buffer_zeroed(m * n);
        let kernel = mm_kernel(m as i32, k as i32, n as i32);
        let launch = sess.launch(&kernel).args(&[&ab, &bb, &cb]).autodma(true).submit()?;
        Ok(sess.wait(&launch)?.digest)
    };
    let single = run(&mut Session::single(aurora()))?;
    let pooled = run(&mut Session::pool(aurora(), 2))?;
    assert_eq!(single, pooled, "pooled launch must be bit-identical to single");
    println!(
        "\ncustom GEMM through a pool=2 session: digest {pooled:#018x} — \
         bit-identical to the single-accelerator launch"
    );
    Ok(())
}

fn main() -> Result<()> {
    println!("darknet_e2e — tiny YOLO-style CNN, conv layers offloaded as GEMMs\n");
    let (logits_auto, log_auto) = run_network(true)?;
    let (logits_remote, log_remote) = run_network(false)?;
    // Both paths must agree bit-for-bit (same kernels, different memories).
    assert_eq!(logits_auto, logits_remote, "offload paths disagree");

    let freq = aurora().accel.freq_mhz as f64;
    println!("{:<22} {:>14} {:>14} {:>9}", "layer", "autodma (cy)", "remote (cy)", "speedup");
    let mut speedups = Vec::new();
    let (mut tot_a, mut tot_r) = (0u64, 0u64);
    for ((name, ca), (_, cr)) in log_auto.iter().zip(&log_remote) {
        println!("{:<22} {:>14} {:>14} {:>8.2}x", name, ca, cr, *cr as f64 / *ca as f64);
        speedups.push(*cr as f64 / *ca as f64);
        tot_a += ca;
        tot_r += cr;
    }
    println!(
        "\nend-to-end conv time: {:.2} ms (AutoDMA) vs {:.2} ms (external memory) \
         at {freq} MHz — {:.2}x, geomean {:.2}x",
        tot_a as f64 / (freq * 1e3),
        tot_r as f64 / (freq * 1e3),
        tot_r as f64 / tot_a as f64,
        geomean(&speedups)
    );
    println!("logits: {:?}", &logits_auto[..5.min(logits_auto.len())]);
    println!("all layers verified against the host golden model: OK");

    pool_digest_check()?;
    Ok(())
}
