//! Network sweep: architecture exploration through the config system.
//!
//! ```sh
//! cargo run --release --example network_sweep
//! ```
//!
//! The §3.3 case study in miniature: sweep the wide on-chip network data
//! width via *config-file overrides* (no recompilation of the platform) and
//! watch DMA, compute, and total cycles respond — including the paper's
//! counter-intuitive result that a wider network can make the application
//! slower when the TCDM interconnect is not co-designed. Also demonstrates
//! multi-cluster (Cyclone-style) and 1..16-core cluster scaling. Each swept
//! configuration is one `Session`.

use herov2::bench_harness::{verify_arrays, Variant};
use herov2::config::{self, parse};
use herov2::workloads;
use herov2::Session;

fn main() -> anyhow::Result<()> {
    let seed = 3;
    let w = workloads::darknet::build(96); // 2D-tiled: sensitive to the sweep
    println!("darknet N=96, handwritten 2D tiling, 8 threads\n");
    println!("{:<28} {:>10} {:>10} {:>10}", "config", "dma (cy)", "comp (cy)", "total");
    for width in [32u32, 64, 128] {
        let cfg = parse::parse_str(&format!(
            "preset = aurora\nnoc.dma_width_bits = {width}\n"
        ))
        .map_err(anyhow::Error::msg)?;
        let mut sess = Session::single(cfg);
        let out = sess.run_workload(&w, Variant::Handwritten, 8, seed)?;
        verify_arrays(&w, &out.arrays, seed)?;
        println!(
            "{:<28} {:>10} {:>10} {:>10}",
            format!("aurora / {width}-bit NoC"),
            out.result.dma_cycles(),
            out.result.compute_cycles(),
            out.result.device_cycles
        );
    }

    println!("\ncluster scaling (gemm N=64, handwritten):");
    for cores in [1usize, 2, 4, 8, 16] {
        let mut cfg = config::aurora();
        cfg.accel.cores_per_cluster = cores;
        let w = workloads::gemm::build(64);
        let mut sess = Session::single(cfg);
        let out = sess.run_workload(&w, Variant::Handwritten, cores as u32, seed)?;
        verify_arrays(&w, &out.arrays, seed)?;
        println!("  {cores:>2} cores: {:>9} cycles", out.result.device_cycles);
    }
    Ok(())
}
