//! AutoDMA tour: what the compiler does to an unmodified OpenMP kernel.
//!
//! ```sh
//! cargo run --release --example autodma_tour
//! ```
//!
//! Shows the §3.2 story end to end: the unmodified source, the transformed
//! load/execute/store form, the zero-code-change speedup vs external-memory
//! execution, and the gap to (and code-size cost of) handwritten tiling.
//! All three variants run through one `Session`.

use herov2::bench_harness::{verify_arrays, Variant};
use herov2::compiler::{autodma, ir, metrics, AutoDmaOpts};
use herov2::config::aurora;
use herov2::workloads;
use herov2::Session;

fn main() -> anyhow::Result<()> {
    let cfg = aurora();
    let w = workloads::gemm::build(64);
    println!("=== gemm, unmodified OpenMP (what the programmer writes) ===");
    println!("{}", ir::pretty(&w.unmodified));

    let (tiled, report) = autodma::transform(&w.unmodified, &AutoDmaOpts::for_config(&cfg))?;
    println!("=== what AutoDMA turns it into (load / execute / store) ===");
    println!("{}", ir::pretty(&tiled));
    println!("tile sides: {:?}; row-wise groups: {:?}; declined (remote): {:?}\n",
        report.tile_sides, report.row_wise, report.remote);

    let seed = 5;
    let mut sess = Session::single(cfg.clone());
    let base = sess.run_workload(&w, Variant::Unmodified, 8, seed)?;
    let auto = sess.run_workload(&w, Variant::AutoDma, 8, seed)?;
    let hand = sess.run_workload(&w, Variant::Handwritten, 8, seed)?;
    for out in [&base, &auto, &hand] {
        verify_arrays(&w, &out.arrays, seed)?;
    }
    let u = metrics::complexity(&w.unmodified);
    let h = metrics::complexity(&w.handwritten);
    let (bc, ac, hc) =
        (base.result.device_cycles, auto.result.device_cycles, hand.result.device_cycles);
    println!("external memory : {bc:>9} cycles");
    println!("AutoDMA         : {ac:>9} cycles ({:.2}x, zero code changes)", bc as f64 / ac as f64);
    println!("handwritten     : {hc:>9} cycles ({:.2}x, {:.1}x more code, {:.1}x cyclomatic)",
        bc as f64 / hc as f64,
        h.loc as f64 / u.loc as f64,
        h.cyclomatic as f64 / u.cyclomatic as f64);
    println!("AutoDMA reaches {:.0}% of the handwritten speedup", 100.0 * hc as f64 / ac as f64);

    // The pathological case (§3.2): covar's column-wise accesses.
    let w = workloads::covar::build(128); // large enough that tiling kicks in
    let (_tiled, report) = autodma::transform(&w.unmodified, &AutoDmaOpts::for_config(&cfg))?;
    println!("\ncovar: AutoDMA declines column-wise groups {:?} — \"the speed-up achieved \
        by the compiler is marginal\" (§3.2)", report.remote);
    Ok(())
}
